"""L2 stage-function tests: shapes, fused-vs-unfused consistency, and the
end-to-end linear-regression semantics of Listing 2 reproduced in JAX."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def test_stage_table_consistent():
    """Every STAGES entry must be callable on its declared shapes."""
    for name, (fn, shapes) in model.STAGES.items():
        args = [
            jnp.asarray(RNG.random(s).astype(np.float32)) for s in shapes
        ]
        out = fn(*args)
        assert isinstance(out, tuple), name
        assert all(o is not None for o in out), name


def test_fused_matches_unfused():
    """lr_fused == standardize -> cbind(ones) -> syrk/gemv composition."""
    x = jnp.asarray(
        RNG.standard_normal((model.LR_ROWS, model.LR_COLS)), jnp.float32
    )
    y = jnp.asarray(RNG.standard_normal(model.LR_ROWS), jnp.float32)
    mean = jnp.asarray(RNG.standard_normal(model.LR_COLS), jnp.float32)
    std = jnp.asarray(
        RNG.random(model.LR_COLS).astype(np.float32) + 0.5, jnp.float32
    )
    a, b = model.lr_fused_block(x, mean, std, y)

    xn = ref.standardize(x, mean, std)
    xb = jnp.concatenate(
        [xn, jnp.ones((model.LR_ROWS, 1), jnp.float32)], axis=1
    )
    np.testing.assert_allclose(a, ref.syrk(xb), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(b, ref.gemv(xb, y), rtol=1e-3, atol=1e-3)


def test_listing2_end_to_end_recovers_coefficients():
    """Full Listing 2 semantics composed from the stage functions recovers
    planted regression coefficients on noiseless data."""
    n, d = 1024, 16
    rng = np.random.default_rng(42)
    x = rng.standard_normal((n, d)).astype(np.float32)
    beta_true = rng.standard_normal(d).astype(np.float32)
    intercept = 0.75
    y = x @ beta_true + intercept

    # lines 8-10: colstats partials -> mean/std -> standardize
    s = np.zeros(d, np.float32)
    sq = np.zeros(d, np.float32)
    for lo in range(0, n, 256):
        bs, bsq = model.lr_colstats_block(jnp.asarray(x[lo : lo + 256]))
        s += np.asarray(bs)
        sq += np.asarray(bsq)
    mean = s / n
    std = np.sqrt(np.maximum(sq / n - mean * mean, 1e-12))

    # lines 11-15 via the fused block, accumulated across row blocks
    a = np.zeros((d + 1, d + 1), np.float32)
    b = np.zeros(d + 1, np.float32)
    for lo in range(0, n, 256):
        pa, pb = model.lr_fused_block(
            jnp.asarray(x[lo : lo + 256]),
            jnp.asarray(mean),
            jnp.asarray(std),
            jnp.asarray(y[lo : lo + 256]),
        )
        a += np.asarray(pa)
        b += np.asarray(pb)

    # lines 13-16: ridge + solve (rust does this natively; numpy here)
    a += np.eye(d + 1, dtype=np.float32) * 1e-3
    beta = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))

    # prediction on standardized features must match y
    xn = (x - mean) / std
    pred = xn @ beta[:-1] + beta[-1]
    np.testing.assert_allclose(pred, y, rtol=2e-2, atol=2e-2)


def test_cc_block_composition_matches_whole():
    """Tiling the CC step across column blocks with max-accumulation (what
    the rust VEE does across tasks) equals the whole-matrix step."""
    n = 2 * model.CC_COLS
    rng = np.random.default_rng(3)
    g = (rng.random((model.CC_ROWS, n)) < 0.01).astype(np.float32)
    c = rng.integers(1, 500, n).astype(np.float32)
    c_row = rng.integers(1, 500, model.CC_ROWS).astype(np.float32)

    whole = ref.cc_propagate(jnp.asarray(g), jnp.asarray(c), jnp.asarray(c_row))

    acc = np.asarray(c_row)
    for lo in range(0, n, model.CC_COLS):
        (u,) = model.cc_propagate_block(
            jnp.asarray(g[:, lo : lo + model.CC_COLS]),
            jnp.asarray(c[lo : lo + model.CC_COLS]),
            jnp.asarray(acc),
        )
        acc = np.maximum(acc, np.asarray(u))
    np.testing.assert_array_equal(acc, np.asarray(whole))
