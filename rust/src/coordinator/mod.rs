//! Distributed DaphneSched (Fig. 5): a coordinator (leader) fronting
//! multiple shared-memory DaphneSched instances (workers) over TCP.
//!
//! The leader is the entry point the DAPHNE runtime talks to: it
//! *distributes* pipeline inputs (row-partitioned sparse blocks),
//! *broadcasts* shared inputs, ships code (DaphneDSL text — the subset
//! interpreter is each worker's local compiler), and collects results.
//! Workers store inputs as they arrive and schedule local tasks with
//! their own shared-memory DaphneSched.
//!
//! std-net threads, no async runtime (tokio is not in the vendored
//! crate set; one blocking thread per connection is plenty for the
//! coordination plane).

pub mod proto;
pub mod worker;

use std::io::{self, BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};

use proto::{read_msg, write_msg, Msg};

use crate::matrix::CsrMatrix;

/// A connected worker.
struct Remote {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    pub cores: u32,
}

impl Remote {
    fn send(&mut self, msg: &Msg) -> io::Result<()> {
        write_msg(&mut self.writer, msg)
    }

    fn recv(&mut self) -> io::Result<Msg> {
        read_msg(&mut self.reader)
    }

    fn expect_ok(&mut self) -> io::Result<()> {
        match self.recv()? {
            Msg::Ok => Ok(()),
            Msg::Error { message } => {
                Err(io::Error::other(format!("worker error: {message}")))
            }
            other => Err(io::Error::other(format!(
                "expected Ok, got {other:?}"
            ))),
        }
    }
}

/// The Fig. 5 coordinator.
pub struct Leader {
    workers: Vec<Remote>,
    /// Row ranges assigned by the last `distribute_sparse`.
    blocks: Vec<(usize, usize)>,
}

/// A collected worker result.
#[derive(Debug, Clone)]
pub struct WorkerResult {
    pub name: String,
    pub scheduled_time: f64,
    pub data: Vec<f32>,
}

impl Leader {
    /// Connect to worker daemons (they listen; see [`worker::serve`]).
    pub fn connect<A: ToSocketAddrs>(addrs: &[A]) -> io::Result<Leader> {
        let mut workers = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = TcpStream::connect(addr)?;
            stream.set_nodelay(true)?;
            let reader = BufReader::new(stream.try_clone()?);
            let writer = BufWriter::new(stream);
            let mut remote = Remote { reader, writer, cores: 0 };
            match remote.recv()? {
                Msg::Hello { cores } => remote.cores = cores,
                other => {
                    return Err(io::Error::other(format!(
                        "expected Hello, got {other:?}"
                    )))
                }
            }
            workers.push(remote);
        }
        Ok(Leader { workers, blocks: Vec::new() })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Row ranges from the last `distribute_sparse`.
    pub fn blocks(&self) -> &[(usize, usize)] {
        &self.blocks
    }

    /// Distribute `g` row-wise (one contiguous block per worker).
    pub fn distribute_sparse(
        &mut self,
        name: &str,
        g: &CsrMatrix,
    ) -> io::Result<()> {
        let n = self.workers.len().max(1);
        let base = g.rows / n;
        let extra = g.rows % n;
        self.blocks.clear();
        let mut start = 0;
        for (i, w) in self.workers.iter_mut().enumerate() {
            let len = base + usize::from(i < extra);
            let end = start + len;
            w.send(&proto::sparse_block_msg(name, g, start, end))?;
            w.expect_ok()?;
            self.blocks.push((start, end));
            start = end;
        }
        Ok(())
    }

    /// Broadcast a dense vector/matrix to every worker.
    pub fn broadcast_dense(
        &mut self,
        name: &str,
        rows: usize,
        cols: usize,
        data: &[f32],
    ) -> io::Result<()> {
        for w in &mut self.workers {
            w.send(&Msg::Dense {
                name: name.to_string(),
                rows: rows as u64,
                cols: cols as u64,
                data: data.to_vec(),
            })?;
            w.expect_ok()?;
        }
        Ok(())
    }

    /// Ship a DaphneDSL script to every worker and collect results.
    pub fn run_script_all(
        &mut self,
        script: &str,
        params: &[(String, String)],
    ) -> io::Result<Vec<WorkerResult>> {
        for w in &mut self.workers {
            w.send(&Msg::RunScript {
                script: script.to_string(),
                params: params.to_vec(),
            })?;
        }
        self.collect()
    }

    fn collect(&mut self) -> io::Result<Vec<WorkerResult>> {
        let mut out = Vec::with_capacity(self.workers.len());
        for w in &mut self.workers {
            match w.recv()? {
                Msg::Result { name, scheduled_time, data } => {
                    out.push(WorkerResult { name, scheduled_time, data })
                }
                Msg::Error { message } => {
                    return Err(io::Error::other(format!(
                        "worker error: {message}"
                    )))
                }
                other => {
                    return Err(io::Error::other(format!(
                        "expected Result, got {other:?}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Distributed connected components: `G` row blocks stay resident on
    /// the workers; the leader broadcasts `c` each round, workers run one
    /// locally-scheduled propagate pass, the leader merges `u` and
    /// checks the fixpoint (Listing 1's loop, distributed per Fig. 5).
    pub fn cc_distributed(
        &mut self,
        g: &CsrMatrix,
        maxi: usize,
    ) -> io::Result<DistributedCc> {
        let n = g.rows;
        self.distribute_sparse("G", g)?;
        let mut c: Vec<f32> = (0..n).map(|i| (i + 1) as f32).collect();
        let mut iterations = 0;
        let mut scheduled_time = 0f64;
        for _ in 0..maxi {
            iterations += 1;
            self.broadcast_dense("c", n, 1, &c)?;
            for w in &mut self.workers {
                w.send(&Msg::CcIterate)?;
            }
            let results = self.collect()?;
            let mut u = vec![0f32; n];
            for (res, &(start, end)) in results.iter().zip(&self.blocks) {
                if res.data.len() != end - start {
                    return Err(io::Error::other(format!(
                        "block result size {} != {}",
                        res.data.len(),
                        end - start
                    )));
                }
                u[start..end].copy_from_slice(&res.data);
                scheduled_time = scheduled_time.max(res.scheduled_time);
            }
            let diff = c.iter().zip(&u).filter(|(a, b)| a != b).count();
            c = u;
            if diff == 0 {
                break;
            }
        }
        Ok(DistributedCc { labels: c, iterations, scheduled_time })
    }

    /// Shut every worker down and close connections.
    pub fn shutdown(mut self) -> io::Result<()> {
        for w in &mut self.workers {
            w.send(&Msg::Shutdown)?;
        }
        Ok(())
    }
}

/// Result of [`Leader::cc_distributed`].
#[derive(Debug, Clone)]
pub struct DistributedCc {
    pub labels: Vec<f32>,
    pub iterations: usize,
    /// Max per-worker scheduled time (critical path of the local
    /// propagate passes).
    pub scheduled_time: f64,
}
