"""L2: JAX stage functions for the two IDA pipelines, calling L1 kernels.

Each public function here is a *pipeline stage* the rust VEE schedules as
a task body. They are lowered once by ``aot.py`` to HLO-text artifacts
with the fixed block shapes below; the rust runtime pads/partitions real
data onto these shapes (zero padding is semantically inert for every
stage — see the kernel docstrings).

All functions return tuples: ``aot.py`` lowers with ``return_tuple=True``
and the rust side unwraps with ``to_tuple1()`` / ``to_tuple()``.
"""

import jax.numpy as jnp

from .kernels import cc_propagate as cc_k
from .kernels import linreg as lr_k

# ---------------------------------------------------------------------------
# Fixed artifact block shapes (f32 everywhere).
#
# CC_ROWS x CC_COLS is the dense adjacency tile the scheduler hands to one
# task on the PJRT path; LR_ROWS x LR_COLS is the row-block of the design
# matrix. 128 columns keeps syrk's output an MXU-shaped 128x128 tile.
# ---------------------------------------------------------------------------
CC_ROWS, CC_COLS = 128, 1024
LR_ROWS, LR_COLS = 256, 128


def cc_propagate_block(g, c, c_row):
    """Listing 1 line 13 over one [CC_ROWS, CC_COLS] adjacency tile."""
    return (cc_k.cc_propagate(g, c, c_row),)


def lr_colstats_block(x):
    """Listing 2 lines 8-9 partials over one row block."""
    s, sq = lr_k.colstats(x)
    return (s, sq)


def lr_standardize_block(x, mean, std):
    """Listing 2 line 10 over one row block."""
    return (lr_k.standardize(x, mean, std),)


def lr_syrk_block(x):
    """Listing 2 line 12 partial (X^T X) over one row block."""
    return (lr_k.syrk(x),)


def lr_gemv_block(x, y):
    """Listing 2 line 15 partial (X^T y) over one row block."""
    return (lr_k.gemv(x, y),)


def lr_fused_block(x, mean, std, y):
    """Fused standardize + syrk + gemv over one row block.

    One dispatch instead of three on the hot path; XLA fuses the
    standardize into both contractions. The +1-bias column of Listing 2
    line 11 is appended here so A and b already include the intercept.
    """
    xn = lr_k.standardize(x, mean, std)
    ones = jnp.ones((xn.shape[0], 1), jnp.float32)
    xb = jnp.concatenate([xn, ones], axis=1)  # [R, C+1]
    a = lr_k.syrk(xb, row_tile=xb.shape[0])
    b = lr_k.gemv(xb, y, row_tile=xb.shape[0])
    return (a, b)


# name -> (fn, example-arg shapes); consumed by aot.py and mirrored in the
# rust artifact registry (runtime/artifact.rs).
STAGES = {
    "cc_propagate": (
        cc_propagate_block,
        ((CC_ROWS, CC_COLS), (CC_COLS,), (CC_ROWS,)),
    ),
    "lr_colstats": (lr_colstats_block, ((LR_ROWS, LR_COLS),)),
    "lr_standardize": (
        lr_standardize_block,
        ((LR_ROWS, LR_COLS), (LR_COLS,), (LR_COLS,)),
    ),
    "lr_syrk": (lr_syrk_block, ((LR_ROWS, LR_COLS),)),
    "lr_gemv": (lr_gemv_block, ((LR_ROWS, LR_COLS), (LR_ROWS,))),
    "lr_fused": (
        lr_fused_block,
        ((LR_ROWS, LR_COLS), (LR_COLS,), (LR_COLS,), (LR_ROWS,)),
    ),
}
