//! Cost models for the DES: what each scheduler action costs in
//! seconds, plus trace-derived calibration of per-node workloads
//! ([`TraceCalibration`]) — the feedback half of online graph
//! retuning.

use std::collections::BTreeMap;

use crate::obs::export::label;
use crate::obs::trace::{fnv1a, TraceEvent, TraceKind};
use crate::topology::Topology;
use crate::util::json::Json;

/// Per-item execution costs of a workload, as a prefix-sum so any chunk
/// `[a, b)` costs `O(1)` to evaluate.
#[derive(Debug, Clone)]
pub struct Workload {
    /// `prefix[i]` = total cost of items `[0, i)`, seconds.
    prefix: Vec<f64>,
    /// Descriptive name for reports.
    pub name: String,
}

impl Workload {
    /// Build from per-item costs (seconds per item).
    pub fn from_costs(name: &str, costs: &[f64]) -> Self {
        let mut prefix = Vec::with_capacity(costs.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &c in costs {
            acc += c;
            prefix.push(acc);
        }
        Workload { prefix, name: name.to_string() }
    }

    /// Uniform per-item cost (the dense linear-regression shape).
    pub fn uniform(name: &str, items: usize, cost: f64) -> Self {
        Workload::from_costs(name, &vec![cost; items])
    }

    pub fn items(&self) -> usize {
        self.prefix.len() - 1
    }

    /// Total cost of items `[a, b)`.
    #[inline]
    pub fn chunk_cost(&self, a: usize, b: usize) -> f64 {
        self.prefix[b] - self.prefix[a]
    }

    /// Total sequential cost.
    pub fn total_cost(&self) -> f64 {
        *self.prefix.last().unwrap()
    }

    /// Rescale so the total sequential cost equals `total` seconds,
    /// preserving the per-item cost *distribution* (a heavy-tailed
    /// workload stays heavy-tailed — only the magnitude is measured by
    /// a trace, not the shape). A zero-cost workload spreads `total`
    /// uniformly instead.
    pub fn scaled_to(&self, total: f64) -> Workload {
        let current = self.total_cost();
        if total <= 0.0 || self.items() == 0 {
            return self.clone();
        }
        if current <= 0.0 {
            return Workload::uniform(
                &self.name,
                self.items(),
                total / self.items() as f64,
            );
        }
        let factor = total / current;
        Workload {
            prefix: self.prefix.iter().map(|p| p * factor).collect(),
            name: self.name.clone(),
        }
    }
}

/// Scheduler-action costs (seconds) plus locality factors. Defaults are
/// the recorded host calibration (see [`super::calibrate`]); benches can
/// re-measure at runtime.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Critical-section time of one lock-protected queue/partitioner
    /// access (lock + `getNextChunk` + unlock) **per worker sharing the
    /// queue**: lock handoff cost grows with the number of contenders
    /// (cache-line bouncing), so a centralized queue on P workers costs
    /// `P * queue_access` per pull while an owner-only per-core deque
    /// costs `1 *`. Serialized across workers — this scaling is what
    /// makes SS "explode" on the central queue and MFSC degrade under
    /// PERCPU, while leaving PERCORE's local pops cheap (§4).
    pub queue_access: f64,
    /// One `fetch_add` access on the atomic central queue. Still
    /// serialized (cache-line ownership migrates) but ~an order of
    /// magnitude cheaper.
    pub atomic_access: f64,
    /// Per-attempt overhead of probing a steal victim (on top of the
    /// victim queue's access cost).
    pub steal_overhead: f64,
    /// Fixed per-task dispatch overhead on the worker (task object
    /// setup, metrics), not serialized.
    pub dispatch: f64,
    /// Multiplier on execution cost for items homed on a remote NUMA
    /// domain (cold remote-socket reads).
    pub remote_exec_factor: f64,
    /// Multiplier on execution cost under the centralized layouts,
    /// where no pre-partitioning aligns blocks with sockets (pages
    /// interleave; on a 2-socket machine ~half the accesses are
    /// remote). 1.0 for single-socket topologies.
    pub interleave_factor: f64,
    /// OS/system interference: preemption-like events arrive per busy
    /// second at this rate (events/s). Dynamic schemes absorb a hit
    /// worker by routing later chunks elsewhere; STATIC's one-shot
    /// blocks take the delay on the critical path — this asymmetry is
    /// what the paper's STATIC-vs-dynamic margins measure on real
    /// machines. 0 disables.
    pub noise_rate: f64,
    /// Mean duration of one interference event (exponential), seconds.
    pub noise_duration: f64,
    /// Extra serialized time per queue access that does NOT scale with
    /// contenders (e.g. an app-level reduction merge performed under a
    /// shared lock at task completion). 0 for plain scheduling.
    pub serialized_extra: f64,
}

impl CostModel {
    /// Recorded host calibration of *this crate's* lean scheduler (see
    /// `calibrate::measure` and EXPERIMENTS.md §Calibration). Values in
    /// seconds. No interference noise — used by unit tests and perf
    /// work where determinism matters.
    pub fn recorded() -> Self {
        CostModel {
            queue_access: 20e-9,
            atomic_access: 9e-9,
            steal_overhead: 15e-9,
            dispatch: 10e-9,
            remote_exec_factor: 1.0, // set per topology by `for_topology`
            interleave_factor: 1.0,
            noise_rate: 0.0,
            noise_duration: 0.0,
            serialized_extra: 0.0,
        }
    }

    /// DAPHNE-runtime-like task-dispatch costs — the configuration the
    /// figures use. The paper's observed effects (SS "explodes" under
    /// central-queue locking; MFSC degrades under PERCPU contention)
    /// imply per-task costs of the DAPHNE runtime's queue path (lock,
    /// task-object allocation, future signaling), a few hundred ns —
    /// not this crate's bare 20 ns partitioner pull. Includes the
    /// OS-interference model active on any real multicore run.
    pub fn daphne_like() -> Self {
        CostModel {
            queue_access: 100e-9, // x contenders: 2us on a 20-core central queue
            atomic_access: 60e-9,
            steal_overhead: 500e-9,
            dispatch: 500e-9,
            remote_exec_factor: 1.0,
            interleave_factor: 1.0,
            noise_rate: 2000.0,
            noise_duration: 4e-6,
            serialized_extra: 0.0,
        }
    }

    /// Specialize locality factors for a machine model: remote execution
    /// costs `remote_numa_factor`; centralized layouts see the average
    /// of local and remote (page interleaving across `s` sockets).
    pub fn for_topology(mut self, topo: &Topology) -> Self {
        let s = topo.sockets.max(1) as f64;
        self.remote_exec_factor = topo.remote_numa_factor;
        self.interleave_factor =
            (1.0 + (s - 1.0) * topo.remote_numa_factor) / s;
        self
    }

    /// Distill measured per-node service times out of a drained trace
    /// stream (real or DES) into a [`TraceCalibration`] — the entry
    /// point of the online graph retuning loop: replay/tune against
    /// `shape.recosted(&calibration)` instead of the assumed costs.
    pub fn calibrate_from_trace(events: &[TraceEvent]) -> TraceCalibration {
        TraceCalibration::from_events(events)
    }
}

/// Measured per-node service totals (seconds), keyed the way the trace
/// export labels nodes: the interned name when one exists, the short
/// hex of the name hash otherwise. Apply with
/// [`GraphShape::recosted`](super::GraphShape::recosted); look up with
/// [`TraceCalibration::service_secs`], which matches a shape node by
/// plain name *or* by the hex spelling of its hash — so calibrations
/// loaded from exported Chrome traces (where graph-node names are
/// usually un-interned) still bind to the right nodes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceCalibration {
    by_label: BTreeMap<String, f64>,
}

impl TraceCalibration {
    /// Sum paired `TaskStart`→`TaskEnd` durations per worker per node
    /// label over a drained, timestamp-sorted stream.
    pub fn from_events(events: &[TraceEvent]) -> TraceCalibration {
        // worker -> (name_hash, TaskStart ts) of the open chunk
        let mut open: BTreeMap<u32, (u64, u64)> = BTreeMap::new();
        let mut cal = TraceCalibration::default();
        for e in events {
            match e.kind {
                TraceKind::TaskStart => {
                    open.insert(e.worker, (e.name_hash, e.ts_ns));
                }
                TraceKind::TaskEnd => {
                    if let Some((nh, start)) = open.remove(&e.worker) {
                        if nh != 0 {
                            let secs = e.ts_ns.saturating_sub(start)
                                as f64
                                / 1e9;
                            *cal.by_label
                                .entry(label(nh))
                                .or_insert(0.0) += secs;
                        }
                    }
                }
                _ => {}
            }
        }
        cal
    }

    /// Load from an exported Chrome trace document (the
    /// `trace_file=<f>.json` a previous run wrote) — the file-based
    /// path behind `tune graph=<app> calibrate=<trace.json>`.
    pub fn from_chrome_trace(doc: &Json) -> TraceCalibration {
        TraceCalibration {
            by_label: crate::obs::report::service_times_from_chrome_trace(
                doc,
            ),
        }
    }

    /// Record a measured total directly (tests, synthetic feeds).
    pub fn insert(&mut self, label: &str, secs: f64) {
        self.by_label.insert(label.to_string(), secs);
    }

    /// Measured total for a shape node, matched by plain name first,
    /// then by the export's hex spelling of the name's hash.
    pub fn service_secs(&self, name: &str) -> Option<f64> {
        self.by_label.get(name).copied().or_else(|| {
            self.by_label.get(&label(fnv1a(name))).copied()
        })
    }

    pub fn len(&self) -> usize {
        self.by_label.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_label.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_sums_answer_chunk_costs() {
        let w = Workload::from_costs("w", &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.items(), 4);
        assert_eq!(w.chunk_cost(0, 4), 10.0);
        assert_eq!(w.chunk_cost(1, 3), 5.0);
        assert_eq!(w.chunk_cost(2, 2), 0.0);
        assert_eq!(w.total_cost(), 10.0);
    }

    #[test]
    fn uniform_workload() {
        let w = Workload::uniform("u", 100, 0.5);
        assert_eq!(w.total_cost(), 50.0);
        assert_eq!(w.chunk_cost(10, 20), 5.0);
    }

    #[test]
    fn scaled_to_preserves_the_distribution() {
        let w = Workload::from_costs("skew", &[1.0, 2.0, 3.0, 4.0]);
        let s = w.scaled_to(20.0);
        assert!((s.total_cost() - 20.0).abs() < 1e-12);
        assert!((s.chunk_cost(0, 1) - 2.0).abs() < 1e-12);
        assert!((s.chunk_cost(3, 4) - 8.0).abs() < 1e-12);
        // zero-cost workloads spread the total uniformly
        let z = Workload::from_costs("zero", &[0.0, 0.0]);
        let zs = z.scaled_to(4.0);
        assert!((zs.chunk_cost(0, 1) - 2.0).abs() < 1e-12);
        // non-positive targets are a no-op
        assert_eq!(w.scaled_to(0.0).total_cost(), w.total_cost());
    }

    #[test]
    fn calibration_from_events_and_lookup() {
        use crate::obs::trace::{fnv1a, TraceEvent, TraceKind};
        let ev = |ts_ns: u64, worker: u32, kind: TraceKind, name: &str| {
            TraceEvent {
                ts_ns,
                worker,
                kind,
                job: 0,
                name_hash: fnv1a(name),
                tag_hash: 0,
            }
        };
        let events = vec![
            ev(0, 0, TraceKind::TaskStart, "dense"),
            ev(2_000_000, 0, TraceKind::TaskEnd, "dense"),
            ev(2_000_000, 1, TraceKind::TaskStart, "dense"),
            ev(3_000_000, 1, TraceKind::TaskEnd, "dense"),
            ev(0, 2, TraceKind::TaskStart, "sparse"),
            ev(500_000, 2, TraceKind::TaskEnd, "sparse"),
        ];
        let cal = CostModel::calibrate_from_trace(&events);
        assert_eq!(cal.len(), 2);
        let dense = cal.service_secs("dense").expect("dense measured");
        assert!((dense - 3e-3).abs() < 1e-12, "summed across workers");
        let sparse = cal.service_secs("sparse").expect("sparse");
        assert!((sparse - 5e-4).abs() < 1e-12);
        assert_eq!(cal.service_secs("absent"), None);
        // direct inserts by plain name bind too
        let mut manual = TraceCalibration::default();
        manual.insert("dense", 1.0);
        assert_eq!(manual.service_secs("dense"), Some(1.0));
    }

    #[test]
    fn topology_factors() {
        let m = CostModel::recorded().for_topology(&Topology::broadwell20());
        assert_eq!(m.remote_exec_factor, 1.9);
        assert!((m.interleave_factor - 1.45).abs() < 1e-12);

        let single = Topology::symmetric("s", 1, 8, 1.0, 1.0);
        let m1 = CostModel::recorded().for_topology(&single);
        assert_eq!(m1.interleave_factor, 1.0);
    }
}
