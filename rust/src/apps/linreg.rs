//! Linear-regression model training (Listing 2).
//!
//! ```text
//! XY = rand(numRows, numCols, 0.0, 1.0, 1, -1);
//! X = XY[, 0:numCols-1];  y = XY[, numCols-1];
//! X = (X - mean(X)) / stddev(X);  X = cbind(X, 1);
//! A = syrk(X) + diag(lambda);  b = gemv(X, y);  beta = solve(A, b);
//! ```
//!
//! Work items are rows of X; per-row cost is uniform (dense data) — the
//! workload where STATIC wins and every dynamic scheme only adds
//! overhead (Fig. 10). The whole training run is **one task graph**
//! expressing its real dependency shape:
//!
//! ```text
//! colstats → stats → standardize → { syrk, gemv }
//! ```
//!
//! `A = X^T X` (syrk) and `b = X^T y` (gemv) only need the standardized
//! rows — they are independent of each other, so in `graph=dag` mode
//! the runtime overlaps them on the resident pool instead of inserting
//! a barrier between them. `solve` is a small sequential epilogue (d×d
//! system).

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::config::SchedConfig;
use crate::matrix::{ops, DenseMatrix};
use crate::runtime::{DeviceClient, Manifest};
use crate::sched::SubmitOpts;
use crate::sim::{GraphShape, NodeModel, Workload};
use crate::topology::Topology;
use crate::util::DisjointMut;
use crate::vee::{report_from_graph, Pipeline, PipelineReport, Vee};

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct LinregResult {
    /// Coefficients (d features + intercept).
    pub beta: Vec<f32>,
    pub report: PipelineReport,
}

/// Workload parameters (paper uses an unspecified random dense matrix;
/// defaults sized so a run takes seconds, like Fig. 10's ~4-40s range).
#[derive(Debug, Clone)]
pub struct LinregSpec {
    pub rows: usize,
    /// Total XY columns (features = cols - 1).
    pub cols: usize,
    pub lambda: f32,
    pub seed: u64,
}

impl Default for LinregSpec {
    fn default() -> Self {
        LinregSpec { rows: 100_000, cols: 65, lambda: 1e-3, seed: 1 }
    }
}

/// Generate XY and split into (X, y) per Listing 2 lines 3-6.
pub fn generate(spec: &LinregSpec) -> (DenseMatrix, Vec<f32>) {
    let xy = DenseMatrix::rand(spec.rows, spec.cols, 0.0, 1.0, spec.seed);
    let x = xy.cols_range(0, spec.cols - 1);
    let y = xy.col(spec.cols - 1);
    (x, y)
}

/// Native execution of the full pipeline under a scheduling config.
///
/// Convenience wrapper: spawns a fresh engine (and worker pool) for the
/// run; sweeps over several configurations should build one [`Vee`] and
/// use [`run_with`] / [`Vee::with_config`] to share the resident pool.
pub fn run_native(
    x: &DenseMatrix,
    y: &[f32],
    lambda: f32,
    topo: &Topology,
    sched: &SchedConfig,
) -> Result<LinregResult, String> {
    run_with(&Vee::new(topo.clone(), sched.clone()), x, y, lambda)
}

/// Native execution on an existing engine: the five scheduled passes
/// are one task graph on the engine's resident pool (no per-stage
/// thread churn); the independent `syrk` and `gemv` reductions overlap
/// in `graph=dag` mode.
pub fn run_with(
    vee: &Vee,
    x: &DenseMatrix,
    y: &[f32],
    lambda: f32,
) -> Result<LinregResult, String> {
    let st = TrainState::new(x.rows, x.cols);
    let mut x_std = x.clone();
    let report = {
        let x_view = DisjointMut::new(&mut x_std.data);
        let pipeline = training_pipeline(x, y, &st, &x_view);
        vee.run_pipeline(&pipeline)
    };
    let beta = st.solve(lambda)?;
    Ok(LinregResult { beta, report })
}

/// Accumulator state of one training pipeline: the column-stats
/// partials, the published mean/std, and the `syrk`/`gemv` reduction
/// targets. One per concurrent tenant in [`run_concurrent`].
struct TrainState {
    n: usize,
    d: usize,
    stats_acc: Mutex<(Vec<f32>, Vec<f32>)>,
    /// mean/std, published by the tiny `stats` node once `colstats` is
    /// fully reduced (the dependency edge makes the `set` happen-before
    /// every `standardize` task).
    norm: OnceLock<(Vec<f32>, Vec<f32>)>,
    a_acc: Mutex<Vec<f32>>,
    b_acc: Mutex<Vec<f32>>,
}

impl TrainState {
    fn new(n: usize, d: usize) -> Self {
        let dd = d + 1;
        TrainState {
            n,
            d,
            stats_acc: Mutex::new((vec![0.0; d], vec![0.0; d])),
            norm: OnceLock::new(),
            a_acc: Mutex::new(vec![0.0; dd * dd]),
            b_acc: Mutex::new(vec![0.0; dd]),
        }
    }

    /// Ridge + solve epilogue (Listing 2 lines 13-16) over the reduced
    /// accumulators.
    fn solve(self, lambda: f32) -> Result<Vec<f32>, String> {
        let dd = self.d + 1;
        let mut a_flat = self.a_acc.into_inner().unwrap();
        let b = self.b_acc.into_inner().unwrap();
        for i in 0..dd {
            a_flat[i * dd + i] += lambda;
        }
        let a = DenseMatrix::from_vec(dd, dd, a_flat);
        ops::cholesky_solve(&a, &b)
    }
}

/// The five-stage training pipeline over borrowed data:
/// `colstats → stats → standardize → { syrk, gemv }`. Shared by
/// [`run_with`] (one pipeline, blocking) and [`run_concurrent`] (many
/// pipelines fused on one session).
fn training_pipeline<'a, 'b: 'a>(
    x: &'a DenseMatrix,
    y: &'a [f32],
    st: &'a TrainState,
    x_view: &'a DisjointMut<'b, f32>,
) -> Pipeline<'a> {
    let n = st.n;
    let d = st.d;
    let dd = d + 1;
    Pipeline::new("linreg")
        .stage("colstats", n, move |_w, range| {
            let mut s = vec![0.0; d];
            let mut sq = vec![0.0; d];
            ops::colstats_rows(x, &mut s, &mut sq, range.start, range.end);
            let mut acc = st.stats_acc.lock().unwrap();
            for c in 0..d {
                acc.0[c] += s[c];
                acc.1[c] += sq[c];
            }
        })
        .stage("stats", 1, move |_w, _range| {
            let acc = st.stats_acc.lock().unwrap();
            let mean: Vec<f32> =
                acc.0.iter().map(|&s| s / n as f32).collect();
            let std: Vec<f32> = acc
                .1
                .iter()
                .zip(&mean)
                .map(|(&sq, &m)| (sq / n as f32 - m * m).max(1e-12).sqrt())
                .collect();
            let _ = st.norm.set((mean, std));
        })
        .stage("standardize", n, move |_w, range| {
            let (mean, std) = st.norm.get().expect("stats node completed");
            let rows = x_view.slice_mut(range.start * d, range.end * d);
            for row in rows.chunks_mut(d) {
                for (c, v) in row.iter_mut().enumerate() {
                    *v = (*v - mean[c]) / std[c];
                }
            }
        })
        // A = X^T X and b = X^T y only need the standardized rows —
        // independent of each other, so they overlap under dag
        // dispatch (shared reads of the rows are sound: the
        // standardize writer completed before either dispatches).
        .stage_after("syrk", n, &["standardize"], move |_w, range| {
            let rows = x_view.slice(range.start * d, range.end * d);
            let mut a = vec![0.0f32; dd * dd];
            for row in rows.chunks(d) {
                for i in 0..d {
                    let xi = row[i];
                    let arow = &mut a[i * dd..i * dd + d];
                    for (j, &xj) in row.iter().enumerate() {
                        arow[j] += xi * xj;
                    }
                    a[i * dd + d] += xi; // bias column
                }
                // bias row: sum of features and count
                for (j, &xj) in row.iter().enumerate() {
                    a[d * dd + j] += xj;
                }
                a[d * dd + d] += 1.0;
            }
            let mut acc = st.a_acc.lock().unwrap();
            for (dst, src) in acc.iter_mut().zip(&a) {
                *dst += src;
            }
        })
        .stage_after("gemv", n, &["standardize"], move |_w, range| {
            let rows = x_view.slice(range.start * d, range.end * d);
            let mut b = vec![0.0f32; dd];
            for (off, row) in rows.chunks(d).enumerate() {
                let yr = y[range.start + off];
                for (i, &xi) in row.iter().enumerate() {
                    b[i] += xi * yr;
                }
                b[d] += yr;
            }
            let mut acc = st.b_acc.lock().unwrap();
            for (dst, src) in acc.iter_mut().zip(&b) {
                *dst += src;
            }
        })
}

/// Train `jobs` identical models *concurrently* through one
/// [`Session`](crate::sched::Session) of the engine's resident pool:
/// every pipeline's five-stage task graph is fused into one merged
/// scheduling horizon (`Session::run_all`, tags `linreg<i>`), with all
/// submission on the calling thread — the executor's workers are the
/// only OS threads involved. Fused submission is dag dispatch by
/// construction (the `graph=barrier` knob does not apply; the CLI runs
/// sequential [`run_with`] loops for that baseline). Panics if `vee`
/// is a one-shot engine.
pub fn run_concurrent(
    vee: &Vee,
    x: &DenseMatrix,
    y: &[f32],
    lambda: f32,
    jobs: usize,
) -> Result<Vec<LinregResult>, String> {
    let session = vee
        .session()
        .expect("run_concurrent needs the persistent executor");
    let states: Vec<TrainState> =
        (0..jobs).map(|_| TrainState::new(x.rows, x.cols)).collect();
    let mut datas: Vec<Vec<f32>> =
        (0..jobs).map(|_| x.data.clone()).collect();
    let graphs = {
        let views: Vec<DisjointMut<'_, f32>> =
            datas.iter_mut().map(|d| DisjointMut::new(d)).collect();
        let pipelines: Vec<Pipeline<'_>> = states
            .iter()
            .zip(&views)
            .map(|(st, view)| training_pipeline(x, y, st, view))
            .collect();
        let specs = pipelines
            .iter()
            .enumerate()
            .map(|(i, p)| {
                (
                    p.to_graph_spec(&vee.sched),
                    SubmitOpts::new().tag(&format!("linreg{i}")),
                )
            })
            .collect();
        session.run_all(specs).map_err(|e| e.to_string())?
    };
    states
        .into_iter()
        .zip(graphs)
        .map(|(st, graph)| {
            let report = report_from_graph(graph);
            Ok(LinregResult { beta: st.solve(lambda)?, report })
        })
        .collect()
}

/// PJRT execution of the fused stage: standardize+syrk+gemv per
/// `[lr_rows, lr_cols]` row block via the `lr_fused` artifact; colstats
/// via the `lr_colstats` artifact. Proves the three-layer composition.
pub fn run_pjrt(
    x: &DenseMatrix,
    y: &[f32],
    lambda: f32,
    device: &DeviceClient,
    manifest: &Manifest,
    topo: &Topology,
    sched: &SchedConfig,
) -> anyhow::Result<LinregResult> {
    let (block_rows, block_cols) = manifest.lr_block;
    anyhow::ensure!(
        x.cols == block_cols,
        "pjrt linreg path requires {} feature columns (artifact shape), got {}",
        block_cols,
        x.cols
    );
    let n = x.rows;
    let d = x.cols;
    let n_blocks = n.div_ceil(block_rows);
    let vee = Vee::new(topo.clone(), sched.clone());
    let t0 = Instant::now();

    let pad_block = |range_start: usize| -> (Vec<f32>, Vec<f32>, usize) {
        let r0 = range_start * block_rows;
        let r1 = ((range_start + 1) * block_rows).min(n);
        let mut xb = vec![0f32; block_rows * d];
        xb[..(r1 - r0) * d]
            .copy_from_slice(&x.data[r0 * d..r1 * d]);
        let mut yb = vec![0f32; block_rows];
        yb[..r1 - r0].copy_from_slice(&y[r0..r1]);
        (xb, yb, r1 - r0)
    };

    // stage 1: colstats partials (items = row blocks)
    let acc: Mutex<(Vec<f32>, Vec<f32>)> =
        Mutex::new((vec![0.0; d], vec![0.0; d]));
    let rep1 = vee.execute(n_blocks, |_w, range| {
        for rb in range.iter() {
            let (xb, _yb, _valid) = pad_block(rb);
            let outs = device
                .run_f32("lr_colstats", vec![xb])
                .expect("lr_colstats failed");
            let mut a = acc.lock().unwrap();
            for c in 0..d {
                a.0[c] += outs[0][c];
                a.1[c] += outs[1][c];
            }
        }
    });
    let (sum, sumsq) = acc.into_inner().unwrap();
    let mean: Vec<f32> = sum.iter().map(|&s| s / n as f32).collect();
    let std: Vec<f32> = sumsq
        .iter()
        .zip(&mean)
        .map(|(&sq, &m)| (sq / n as f32 - m * m).max(1e-12).sqrt())
        .collect();

    // stage 2: fused standardize+syrk+gemv partials.
    //
    // Zero-padded rows standardize to (0-mean)/std != 0, so instead of
    // relying on inert padding we run the artifact on the padded block
    // and subtract the padding rows' closed-form contribution: each pad
    // row contributes z z^T to A (z = (-mean/std)·featured, 1 bias) and
    // 0 to b (y pad = 0).
    let dd = d + 1;
    let mut z = vec![0f32; dd];
    for c in 0..d {
        z[c] = -mean[c] / std[c];
    }
    z[d] = 1.0;
    let acc2: Mutex<(Vec<f32>, Vec<f32>)> =
        Mutex::new((vec![0.0; dd * dd], vec![0.0; dd]));
    let rep2 = vee.execute(n_blocks, |_w, range| {
        for rb in range.iter() {
            let (xb, yb, valid) = pad_block(rb);
            let outs = device
                .run_f32(
                    "lr_fused",
                    vec![xb, mean.clone(), std.clone(), yb],
                )
                .expect("lr_fused failed");
            let pad = block_rows - valid;
            let mut a = acc2.lock().unwrap();
            for i in 0..dd {
                for j in 0..dd {
                    let mut v = outs[0][i * dd + j];
                    if pad > 0 {
                        v -= pad as f32 * z[i] * z[j];
                    }
                    a.0[i * dd + j] += v;
                }
                a.1[i] += outs[1][i];
            }
        }
    });

    // wall-clock of the scheduled pipeline only (excludes the serial
    // solve epilogue, matching what the native path's graph makespan
    // covers — so total_time() is comparable across backends)
    let wall_time = t0.elapsed().as_secs_f64();

    let (mut a_flat, b) = acc2.into_inner().unwrap();
    for i in 0..dd {
        a_flat[i * dd + i] += lambda;
    }
    let a = DenseMatrix::from_vec(dd, dd, a_flat);
    let beta = ops::cholesky_solve(&a, &b).map_err(anyhow::Error::msg)?;

    Ok(LinregResult {
        beta,
        report: PipelineReport {
            pipeline: "linreg(pjrt)".into(),
            stages: vec![
                ("colstats".into(), rep1),
                ("fused".into(), rep2),
            ],
            wall_time,
        },
    })
}

/// DES workload for the three scheduled passes over the rows: uniform
/// per-row cost (dense data). `per_row` comes from host calibration.
pub fn workload(rows: usize, per_row: f64) -> Workload {
    Workload::uniform("linreg_row", rows, per_row)
}

/// The training pipeline's real task graph as a cost-described
/// [`GraphShape`] for virtual-time replay — the same
/// `colstats → stats → standardize → {syrk, gemv}` structure
/// [`run_with`] submits to the executor. Per-item costs are uniform
/// (dense rows) at the calibrated `per_row`; the fused third pass is
/// split 3:1 between `syrk` (O(d²) per row) and `gemv` (O(d) per row)
/// so the shape's total cost matches the three full sweeps the figures
/// model ([`workload`] × 3).
pub fn graph_shape(rows: usize, per_row: f64) -> GraphShape {
    GraphShape::new("linreg")
        .node(NodeModel::uniform("colstats", rows, per_row))
        .node(NodeModel::uniform("stats", 1, per_row).after("colstats"))
        .node(NodeModel::uniform("standardize", rows, per_row).after("stats"))
        .node(NodeModel::uniform("syrk", rows, per_row * 0.75).after("standardize"))
        .node(NodeModel::uniform("gemv", rows, per_row * 0.25).after("standardize"))
}

/// Fit quality: RMSE of predictions vs targets on standardized features.
pub fn rmse(x: &DenseMatrix, y: &[f32], beta: &[f32]) -> f64 {
    let d = x.cols;
    // recompute mean/std like the pipeline
    let n = x.rows;
    let mut mean = vec![0f32; d];
    let mut sq = vec![0f32; d];
    ops::colstats_rows(x, &mut mean, &mut sq, 0, n);
    for c in 0..d {
        mean[c] /= n as f32;
        sq[c] = (sq[c] / n as f32 - mean[c] * mean[c]).max(1e-12).sqrt();
    }
    let mut err = 0f64;
    for r in 0..n {
        let row = x.row(r);
        let mut pred = beta[d]; // intercept
        for c in 0..d {
            pred += beta[c] * (row[c] - mean[c]) / sq[c];
        }
        err += ((pred - y[r]) as f64).powi(2);
    }
    (err / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{QueueLayout, Scheme, VictimStrategy};
    use crate::util::Rng;

    fn planted(n: usize, d: usize, seed: u64) -> (DenseMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x = DenseMatrix::rand(n, d, -1.0, 1.0, rng.next_u64());
        let beta: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n)
            .map(|r| {
                x.row(r).iter().zip(&beta).map(|(a, b)| a * b).sum::<f32>()
                    + 0.5
            })
            .collect();
        (x, y, beta)
    }

    fn topo() -> Topology {
        Topology::symmetric("t", 1, 4, 1.0, 1.0)
    }

    #[test]
    fn recovers_planted_model() {
        let (x, y, _) = planted(2000, 8, 42);
        let r = run_native(&x, &y, 1e-4, &topo(), &SchedConfig::default())
            .unwrap();
        assert_eq!(r.beta.len(), 9);
        let e = rmse(&x, &y, &r.beta);
        assert!(e < 1e-2, "rmse {e}");
    }

    #[test]
    fn concurrent_trainings_agree_with_sequential() {
        let (x, y, _) = planted(1200, 6, 11);
        let vee =
            crate::vee::Vee::new(topo(), SchedConfig::default());
        let base = run_with(&vee, &x, &y, 1e-4).unwrap();
        let results = run_concurrent(&vee, &x, &y, 1e-4, 3).unwrap();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert_eq!(r.beta.len(), base.beta.len());
            for (a, b) in r.beta.iter().zip(&base.beta) {
                assert!(
                    (a - b).abs() < 1e-3,
                    "concurrent beta {a} vs sequential {b}"
                );
            }
            assert_eq!(r.report.stages.len(), 5);
        }
    }

    #[test]
    fn all_schemes_agree_on_beta() {
        let (x, y, _) = planted(1500, 6, 7);
        let base = run_native(&x, &y, 1e-4, &topo(), &SchedConfig::default())
            .unwrap()
            .beta;
        for scheme in Scheme::ALL {
            for layout in [
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerCore,
            ] {
                let cfg = SchedConfig::default()
                    .with_scheme(scheme)
                    .with_layout(layout)
                    .with_victim(VictimStrategy::Rnd);
                let beta =
                    run_native(&x, &y, 1e-4, &topo(), &cfg).unwrap().beta;
                for (a, b) in base.iter().zip(&beta) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "{scheme:?}/{layout:?}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn generate_splits_xy() {
        let spec = LinregSpec { rows: 100, cols: 9, lambda: 1e-3, seed: 3 };
        let (x, y) = generate(&spec);
        assert_eq!(x.rows, 100);
        assert_eq!(x.cols, 8);
        assert_eq!(y.len(), 100);
    }

    #[test]
    fn report_covers_all_graph_stages() {
        let (x, y, _) = planted(500, 4, 9);
        let r = run_native(&x, &y, 1e-3, &topo(), &SchedConfig::default())
            .unwrap();
        let names: Vec<&str> =
            r.report.stages.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["colstats", "stats", "standardize", "syrk", "gemv"]
        );
        for (name, rep) in &r.report.stages {
            let want = if name == "stats" { 1 } else { 500 };
            assert_eq!(rep.total_items(), want, "{name}");
        }
        assert!(r.report.total_time() > 0.0);
        assert!(r.report.serial_time() >= 0.0);
    }

    #[test]
    fn barrier_and_dag_modes_agree_on_beta() {
        use crate::config::GraphMode;
        use crate::vee::Vee;
        let (x, y, _) = planted(1200, 5, 11);
        let dag = Vee::new(topo(), SchedConfig::default());
        let barrier = Vee::new(topo(), SchedConfig::default())
            .with_graph_mode(GraphMode::Barrier);
        let beta_dag = run_with(&dag, &x, &y, 1e-3).unwrap().beta;
        let beta_bar = run_with(&barrier, &x, &y, 1e-3).unwrap().beta;
        for (i, (p, q)) in beta_dag.iter().zip(&beta_bar).enumerate() {
            assert!((p - q).abs() < 1e-3, "beta[{i}]: {p} vs {q}");
        }
    }

    #[test]
    fn graph_shape_matches_pipeline_structure() {
        use crate::config::GraphMode;
        use crate::sim::{self, CostModel};
        let shape = graph_shape(10_000, 1e-7);
        assert_eq!(
            shape.node_names().collect::<Vec<_>>(),
            vec!["colstats", "stats", "standardize", "syrk", "gemv"]
        );
        // total cost = three full row sweeps (+ the tiny stats node)
        let sweeps = 3.0 * 10_000.0 * 1e-7;
        assert!((shape.total_cost() - sweeps - 1e-7).abs() < 1e-12);
        // syrk and gemv overlap in dag replay: gemv (the cheap
        // reduction) finishes inside syrk's span instead of after it
        let out = sim::replay(
            &shape,
            &Topology::broadwell20(),
            &SchedConfig::default(),
            &CostModel::recorded(),
            GraphMode::Dag,
        )
        .unwrap();
        let (syrk, gemv) = (out.node("syrk").unwrap(), out.node("gemv").unwrap());
        assert_eq!(syrk.start, gemv.start);
        assert!(out.makespan() < out.serial_time());
    }

    #[test]
    fn workload_is_uniform() {
        let w = workload(1000, 2e-8);
        assert!((w.total_cost() - 2e-5).abs() / 2e-5 < 1e-9);
        // prefix-sum float rounding: compare halves approximately
        let (a, b) = (w.chunk_cost(0, 500), w.chunk_cost(500, 1000));
        assert!((a - b).abs() / a < 1e-9, "{a} vs {b}");
    }
}
