//! Machine-topology model: sockets, NUMA domains, cores, device classes.
//!
//! The paper evaluates on a 2×10-core Intel Broadwell and a 2×28-core
//! Intel Cascade Lake. Neither is available here, so the topology is an
//! explicit model consumed by two executors that share all scheduler
//! code:
//!
//! - the real-thread worker pool ([`crate::sched::Executor`]), which
//!   uses the topology for NUMA-aware victim selection, queue grouping,
//!   and per-device-class worker pools
//!   ([`crate::sched::placement::DevicePools`]);
//! - the discrete-event simulator ([`crate::sim`]), which additionally
//!   uses the per-domain latency factors to model remote-steal and
//!   remote-queue access costs, and the per-place speed factors to model
//!   accelerator pools.
//!
//! [`Topology::heterogeneous`] attaches accelerator pools (mixed
//! [`DeviceClass`] places with per-class speed factors) to a CPU
//! machine; [`Topology::symmetric`] is the CPU-only special case.
//! [`Topology::hetero20`] / [`Topology::hetero56`] are the modelled
//! variants of the paper's two machines with a GPU pool attached.

/// Kind of compute device a worker fronts. The DAPHNE worker manager
/// also creates threads that launch kernels on accelerators; the paper
/// evaluates CPU-only, but the dimension is first-class here: the
/// scheduler partitions its workers into one pool per device class
/// ([`crate::sched::placement`]) and graph nodes carry a
/// [`Placement`](crate::sched::placement::Placement) routing them to a
/// pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Cpu,
    Gpu,
    Fpga,
}

impl DeviceClass {
    pub const ALL: [DeviceClass; 3] =
        [DeviceClass::Cpu, DeviceClass::Gpu, DeviceClass::Fpga];

    pub fn name(&self) -> &'static str {
        match self {
            DeviceClass::Cpu => "cpu",
            DeviceClass::Gpu => "gpu",
            DeviceClass::Fpga => "fpga",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Some(DeviceClass::Cpu),
            "gpu" => Some(DeviceClass::Gpu),
            "fpga" => Some(DeviceClass::Fpga),
            _ => None,
        }
    }
}

/// One hardware thread (one DaphneSched worker).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorePlace {
    /// Global worker/core id, dense in `0..n_cores`.
    pub core: usize,
    /// Socket == NUMA domain on both evaluated machines; accelerator
    /// pools occupy their own domains after the CPU sockets.
    pub socket: usize,
    pub device: DeviceClass,
    /// Relative single-core speed of this place vs the machine's CPU
    /// cores (1.0 for CPU places; e.g. 4.0 for an accelerator device
    /// modelled at 4× CPU speed). Multiplies [`Topology::core_speed`].
    pub speed: f64,
}

/// A machine: cores grouped into sockets/NUMA domains plus the latency
/// factors the simulator uses.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub places: Vec<CorePlace>,
    /// Number of NUMA-like domains: the CPU sockets plus one domain per
    /// attached accelerator pool (heterogeneous topologies).
    pub sockets: usize,
    /// Relative cost multiplier for touching memory/queues on a remote
    /// NUMA domain (≈2x on the evaluated Xeons).
    pub remote_numa_factor: f64,
    /// Single-core relative speed vs the Broadwell baseline. Per-place
    /// [`CorePlace::speed`] factors multiply this (see
    /// [`Topology::speed_of`]).
    pub core_speed: f64,
}

impl Topology {
    /// Build a symmetric multi-socket CPU topology — the CPU-only
    /// special case of [`Topology::heterogeneous`].
    pub fn symmetric(
        name: &str,
        sockets: usize,
        cores_per_socket: usize,
        remote_numa_factor: f64,
        core_speed: f64,
    ) -> Self {
        Topology::heterogeneous(
            name,
            sockets,
            cores_per_socket,
            remote_numa_factor,
            core_speed,
            &[],
        )
    }

    /// Build a machine with `sockets × cores_per_socket` CPU places plus
    /// accelerator places per `accel` entry: `(class, devices, speed)`
    /// adds `devices` places of `class`, each `speed`× as fast as one
    /// CPU core of this machine. Each entry occupies its own NUMA-like
    /// domain after the CPU sockets (device memory is remote to every
    /// CPU socket and vice versa). Note that the scheduler pools workers
    /// *by class* ([`crate::sched::placement::DevicePools`]): several
    /// entries of the same class merge into one pool and must share one
    /// `speed` (enforced at pool construction).
    pub fn heterogeneous(
        name: &str,
        sockets: usize,
        cores_per_socket: usize,
        remote_numa_factor: f64,
        core_speed: f64,
        accel: &[(DeviceClass, usize, f64)],
    ) -> Self {
        let mut places: Vec<CorePlace> = (0..sockets * cores_per_socket)
            .map(|core| CorePlace {
                core,
                socket: core / cores_per_socket,
                device: DeviceClass::Cpu,
                speed: 1.0,
            })
            .collect();
        let mut domain = sockets;
        for &(device, devices, speed) in accel {
            for _ in 0..devices {
                places.push(CorePlace {
                    core: places.len(),
                    socket: domain,
                    device,
                    speed,
                });
            }
            domain += 1;
        }
        Topology {
            name: name.to_string(),
            places,
            sockets: domain,
            remote_numa_factor,
            core_speed,
        }
    }

    /// The paper's 2×10-core Intel E5-2640 v4 (Broadwell), 64 GB.
    pub fn broadwell20() -> Self {
        Topology::symmetric("broadwell20", 2, 10, 1.9, 1.0)
    }

    /// The paper's 2×28-core Intel Xeon Gold 6258R (Cascade Lake), 1.5 TB.
    pub fn cascadelake56() -> Self {
        Topology::symmetric("cascadelake56", 2, 28, 2.1, 1.15)
    }

    /// Modelled heterogeneous variant of the 20-core machine: the
    /// Broadwell CPU sockets plus a 4-device GPU pool, each device
    /// modelled at 4× one CPU core (a modest PCIe accelerator).
    pub fn hetero20() -> Self {
        Topology::heterogeneous(
            "hetero20",
            2,
            10,
            1.9,
            1.0,
            &[(DeviceClass::Gpu, 4, 4.0)],
        )
    }

    /// Modelled heterogeneous variant of the 56-core machine: the
    /// Cascade Lake CPU sockets plus an 8-device GPU pool at 4× CPU
    /// speed — the machine the placement acceptance tests and
    /// `figure hetero` run on.
    pub fn hetero56() -> Self {
        Topology::heterogeneous(
            "hetero56",
            2,
            28,
            2.1,
            1.15,
            &[(DeviceClass::Gpu, 8, 4.0)],
        )
    }

    /// A topology matching the current host (single NUMA domain assumed;
    /// used by the real-thread executor for tests/examples). Detection
    /// runs once per process; see [`Topology::host_shared`] for the
    /// allocation-free handle.
    pub fn host() -> Self {
        (*Self::host_shared()).clone()
    }

    /// Shared handle to the host topology: detected once, then shared
    /// via `Arc` (the persistent executor and `Vee::host_default` clone
    /// the `Arc`, not the topology).
    pub fn host_shared() -> std::sync::Arc<Self> {
        static HOST: std::sync::OnceLock<std::sync::Arc<Topology>> =
            std::sync::OnceLock::new();
        std::sync::Arc::clone(HOST.get_or_init(|| {
            let n = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            std::sync::Arc::new(Topology::symmetric("host", 1, n, 1.0, 1.0))
        }))
    }

    /// Resolve a preset by name (CLI / config).
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "broadwell20" | "broadwell" => Some(Self::broadwell20()),
            "cascadelake56" | "cascadelake" => Some(Self::cascadelake56()),
            "hetero20" => Some(Self::hetero20()),
            "hetero56" | "hetero" => Some(Self::hetero56()),
            "host" => Some(Self::host()),
            _ => None,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.places.len()
    }

    pub fn cores_per_socket(&self) -> usize {
        self.places.len() / self.sockets.max(1)
    }

    /// NUMA domain of a core.
    pub fn socket_of(&self, core: usize) -> usize {
        self.places[core].socket
    }

    /// Whether two cores share a NUMA domain.
    pub fn same_domain(&self, a: usize, b: usize) -> bool {
        self.socket_of(a) == self.socket_of(b)
    }

    /// Cores in the given NUMA domain.
    pub fn domain_cores(&self, socket: usize) -> Vec<usize> {
        self.places
            .iter()
            .filter(|p| p.socket == socket)
            .map(|p| p.core)
            .collect()
    }

    /// Relative cost factor for core `from` accessing memory homed on
    /// `to`'s domain.
    pub fn access_factor(&self, from: usize, to: usize) -> f64 {
        if self.same_domain(from, to) {
            1.0
        } else {
            self.remote_numa_factor
        }
    }

    /// Effective relative speed of one core: the machine baseline times
    /// the place's per-class factor.
    pub fn speed_of(&self, core: usize) -> f64 {
        self.core_speed * self.places[core].speed
    }

    /// Distinct device classes present, in order of first appearance
    /// (CPU first for every built-in constructor).
    pub fn device_classes(&self) -> Vec<DeviceClass> {
        let mut out = Vec::new();
        for p in &self.places {
            if !out.contains(&p.device) {
                out.push(p.device);
            }
        }
        out
    }

    pub fn has_class(&self, class: DeviceClass) -> bool {
        self.places.iter().any(|p| p.device == class)
    }

    /// Number of places of the given device class.
    pub fn class_cores(&self, class: DeviceClass) -> usize {
        self.places.iter().filter(|p| p.device == class).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadwell_shape() {
        let t = Topology::broadwell20();
        assert_eq!(t.n_cores(), 20);
        assert_eq!(t.sockets, 2);
        assert_eq!(t.cores_per_socket(), 10);
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(9), 0);
        assert_eq!(t.socket_of(10), 1);
        assert_eq!(t.socket_of(19), 1);
    }

    #[test]
    fn cascadelake_shape() {
        let t = Topology::cascadelake56();
        assert_eq!(t.n_cores(), 56);
        assert_eq!(t.cores_per_socket(), 28);
        assert_eq!(t.domain_cores(1).len(), 28);
        assert!(t.domain_cores(1).iter().all(|&c| c >= 28));
    }

    #[test]
    fn access_factors() {
        let t = Topology::broadwell20();
        assert_eq!(t.access_factor(0, 5), 1.0);
        assert_eq!(t.access_factor(0, 15), 1.9);
        assert!(t.same_domain(3, 7));
        assert!(!t.same_domain(3, 17));
    }

    #[test]
    fn presets_resolve() {
        assert!(Topology::preset("broadwell20").is_some());
        assert!(Topology::preset("cascadelake").is_some());
        assert!(Topology::preset("hetero20").is_some());
        assert!(Topology::preset("hetero56").is_some());
        assert!(Topology::preset("host").is_some());
        assert!(Topology::preset("riscv").is_none());
    }

    #[test]
    fn heterogeneous_appends_accelerator_domains() {
        let t = Topology::heterogeneous(
            "h",
            2,
            4,
            1.5,
            1.0,
            &[(DeviceClass::Gpu, 2, 4.0), (DeviceClass::Fpga, 1, 2.0)],
        );
        assert_eq!(t.n_cores(), 11);
        assert_eq!(t.sockets, 4, "2 CPU sockets + 2 accelerator domains");
        // CPU places unchanged vs the symmetric layout
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(7), 1);
        assert_eq!(t.places[0].device, DeviceClass::Cpu);
        assert_eq!(t.places[0].speed, 1.0);
        // GPU devices on their own domain, 4x speed
        assert_eq!(t.places[8].device, DeviceClass::Gpu);
        assert_eq!(t.socket_of(8), 2);
        assert_eq!(t.socket_of(9), 2);
        assert_eq!(t.speed_of(8), 4.0);
        // FPGA after the GPUs
        assert_eq!(t.places[10].device, DeviceClass::Fpga);
        assert_eq!(t.socket_of(10), 3);
        // accelerator memory is remote to the CPU sockets
        assert!(!t.same_domain(0, 8));
        assert_eq!(t.access_factor(0, 8), 1.5);
        assert_eq!(
            t.device_classes(),
            vec![DeviceClass::Cpu, DeviceClass::Gpu, DeviceClass::Fpga]
        );
        assert_eq!(t.class_cores(DeviceClass::Gpu), 2);
        assert!(t.has_class(DeviceClass::Fpga));
    }

    #[test]
    fn symmetric_is_the_cpu_only_special_case() {
        let t = Topology::broadwell20();
        assert_eq!(t.device_classes(), vec![DeviceClass::Cpu]);
        assert!(!t.has_class(DeviceClass::Gpu));
        assert!(t.places.iter().all(|p| p.speed == 1.0));
        assert_eq!(t.speed_of(0), t.core_speed);
    }

    #[test]
    fn modelled_hetero_machines() {
        let h20 = Topology::hetero20();
        assert_eq!(h20.n_cores(), 24);
        assert_eq!(h20.class_cores(DeviceClass::Cpu), 20);
        assert_eq!(h20.class_cores(DeviceClass::Gpu), 4);
        let h56 = Topology::hetero56();
        assert_eq!(h56.n_cores(), 64);
        assert_eq!(h56.class_cores(DeviceClass::Gpu), 8);
        // the accelerator pool is modelled at 4x CPU speed
        let gpu0 = h56.places.iter().position(|p| p.device == DeviceClass::Gpu).unwrap();
        assert_eq!(h56.speed_of(gpu0), 4.0 * h56.core_speed);
    }

    #[test]
    fn device_class_names_roundtrip() {
        for c in DeviceClass::ALL {
            assert_eq!(DeviceClass::parse(c.name()), Some(c));
        }
        assert_eq!(DeviceClass::parse("tpu"), None);
    }

    #[test]
    fn host_has_at_least_one_core() {
        assert!(Topology::host().n_cores() >= 1);
    }

    #[test]
    fn host_shared_detects_once() {
        let a = Topology::host_shared();
        let b = Topology::host_shared();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "host topology must be cached");
        assert_eq!(Topology::host().n_cores(), a.n_cores());
    }
}
