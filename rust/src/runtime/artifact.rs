//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Parsed with the in-repo JSON parser.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// One lowered stage as described by `manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Input shapes (row-major dims), f32.
    pub args: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub stages: Vec<StageSpec>,
    /// `(rows, cols)` of the CC adjacency tile artifact.
    pub cc_block: (usize, usize),
    /// `(rows, cols)` of the LR row-block artifact.
    pub lr_block: (usize, usize),
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let shapes = doc
            .get("block_shapes")
            .ok_or_else(|| anyhow!("manifest missing block_shapes"))?;
        let pair = |key: &str| -> Result<(usize, usize)> {
            let arr = shapes
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing block_shapes.{key}"))?;
            Ok((
                arr.first().and_then(Json::as_usize).unwrap_or(0),
                arr.get(1).and_then(Json::as_usize).unwrap_or(0),
            ))
        };
        let stages_obj = doc
            .get("stages")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing stages"))?;
        let mut stages = Vec::new();
        for (name, entry) in stages_obj {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("stage {name}: missing file"))?
                .to_string();
            let args = entry
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("stage {name}: missing args"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| {
                            dims.iter().filter_map(Json::as_usize).collect()
                        })
                        .ok_or_else(|| anyhow!("stage {name}: bad arg shape"))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("stage {name}: missing outputs"))?;
            stages.push(StageSpec { name: name.clone(), file, args, outputs });
        }
        Ok(Manifest { stages, cc_block: pair("cc")?, lr_block: pair("lr")? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "block_shapes": {"cc": [128, 1024], "lr": [256, 128]},
      "stages": {
        "cc_propagate": {"file": "cc_propagate.hlo.txt",
                          "args": [[128, 1024], [1024], [128]],
                          "outputs": 1, "dtype": "f32"},
        "lr_fused": {"file": "lr_fused.hlo.txt",
                      "args": [[256, 128], [128], [128], [256]],
                      "outputs": 2, "dtype": "f32"}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.cc_block, (128, 1024));
        assert_eq!(m.lr_block, (256, 128));
        assert_eq!(m.stages.len(), 2);
        let cc = m.stages.iter().find(|s| s.name == "cc_propagate").unwrap();
        assert_eq!(cc.args.len(), 3);
        assert_eq!(cc.args[0], vec![128, 1024]);
        assert_eq!(cc.outputs, 1);
        let lr = m.stages.iter().find(|s| s.name == "lr_fused").unwrap();
        assert_eq!(lr.outputs, 2);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"block_shapes": {"cc": [1,1], "lr": [1,1]}}"#).is_err());
        let no_file = r#"{
          "block_shapes": {"cc": [1,1], "lr": [1,1]},
          "stages": {"x": {"args": [[1]], "outputs": 1}}
        }"#;
        assert!(Manifest::parse(no_file).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration check against the actual `make artifacts` output.
        let path = std::path::Path::new("artifacts/manifest.json");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(path).unwrap();
        assert!(m.stages.iter().any(|s| s.name == "cc_propagate"));
        assert!(m.stages.iter().any(|s| s.name == "lr_fused"));
        assert_eq!(m.cc_block, (128, 1024));
    }
}
