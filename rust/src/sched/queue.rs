//! Queue management (paper §3): the three work-queue layouts.
//!
//! 1. **Centralized** — one queue per device class; workers self-schedule
//!    chunks straight from the shared partitioner. Two implementations:
//!    the lock-based one the paper measured, and the atomic one its §5
//!    future work proposes (precomputed chunk boundaries served by a
//!    single `fetch_add`) — compared in `benches/ablations.rs`.
//! 2. **Per-group (PERCPU)** — one queue per NUMA domain; the input is
//!    pre-partitioned into one contiguous block per domain (this is what
//!    gives STATIC its locality win in Figs. 8b/9b).
//! 3. **Per-core (PERCORE)** — one queue per worker; maximal stealing
//!    freedom, no pre-partitioning benefit beyond the owner block.
//!
//! In the multi-queue layouts every queue owns a [`Partitioner`] over its
//! block, so a thief's steal granularity follows the chosen
//! self-scheduling scheme (contribution C.2).
//!
//! Sources are **job-scoped**: the persistent executor
//! ([`crate::sched::executor`]) builds one source per submitted job and
//! multiplexes many of them over the same resident workers. Sources
//! never refill, so exhaustion is permanent — workers detect it through
//! an empty pull + steal round and move on to another job's source;
//! [`TaskSource::is_exhausted`] / [`TaskSource::remaining_total`]
//! expose the same invariant for steal heuristics, assertions and
//! tests (in-flight tasks of an exhausted source may still be
//! executing on other workers).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::partitioner::{Partitioner, PartitionerOptions, Scheme};
use super::task::TaskRange;
use crate::topology::Topology;

/// Work-queue layout (paper Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueLayout {
    /// One shared queue; `atomic` selects the lock-free variant.
    Centralized { atomic: bool },
    /// One queue per NUMA domain (the paper's PERCPU).
    PerGroup,
    /// One queue per worker (the paper's PERCORE).
    PerCore,
}

impl QueueLayout {
    pub fn name(&self) -> &'static str {
        match self {
            QueueLayout::Centralized { atomic: false } => "CENTRAL",
            QueueLayout::Centralized { atomic: true } => "CENTRAL-ATOMIC",
            QueueLayout::PerGroup => "PERCPU",
            QueueLayout::PerCore => "PERCORE",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "CENTRAL" | "CENTRALIZED" => {
                Some(QueueLayout::Centralized { atomic: false })
            }
            "CENTRAL-ATOMIC" | "ATOMIC" => {
                Some(QueueLayout::Centralized { atomic: true })
            }
            "PERCPU" | "PERGROUP" | "PERSOCKET" => Some(QueueLayout::PerGroup),
            "PERCORE" | "PERWORKER" => Some(QueueLayout::PerCore),
            _ => None,
        }
    }

    /// Whether this layout uses work-stealing.
    pub fn steals(&self) -> bool {
        !matches!(self, QueueLayout::Centralized { .. })
    }
}

/// A successful task acquisition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pull {
    pub task: TaskRange,
    /// Which queue served it.
    pub queue: usize,
    /// True iff the task came from a queue the worker does not own.
    pub stolen: bool,
}

/// Common interface over the three layouts. `pull_local` serves a
/// worker's own queue (or the central queue); `pull_from` targets a
/// specific victim queue during stealing.
pub trait TaskSource: Send + Sync {
    fn pull_local(&self, worker: usize) -> Option<Pull>;
    fn pull_from(&self, queue: usize, worker: usize) -> Option<Pull>;
    /// Number of queues (1 for centralized).
    fn n_queues(&self) -> usize;
    /// The queue `worker` owns.
    fn queue_of(&self, worker: usize) -> usize;
    /// Items still unclaimed in `queue` (steal heuristics, tests).
    fn remaining_in(&self, queue: usize) -> usize;

    /// Total unclaimed items across every queue.
    fn remaining_total(&self) -> usize {
        (0..self.n_queues()).map(|q| self.remaining_in(q)).sum()
    }

    /// True once every queue is empty. Partitioners never refill, so an
    /// exhausted job-scoped source stays exhausted; items already pulled
    /// may still be executing.
    fn is_exhausted(&self) -> bool {
        self.remaining_total() == 0
    }
}

// ---------------------------------------------------------------------------
// centralized, lock-based (the paper's measured implementation)
// ---------------------------------------------------------------------------

/// One shared partitioner behind a mutex — every access serializes,
/// which is exactly the contention the paper observes (and which makes
/// SS "explode" on 56 cores).
pub struct CentralLocked {
    part: Partitioner,
}

impl CentralLocked {
    pub fn new(
        scheme: Scheme,
        total: usize,
        workers: usize,
        opts: &PartitionerOptions,
    ) -> Self {
        CentralLocked { part: Partitioner::new(scheme, 0, total, workers, opts) }
    }
}

impl TaskSource for CentralLocked {
    fn pull_local(&self, _worker: usize) -> Option<Pull> {
        self.part
            .next_chunk()
            .map(|task| Pull { task, queue: 0, stolen: false })
    }

    fn pull_from(&self, _queue: usize, worker: usize) -> Option<Pull> {
        self.pull_local(worker)
    }

    fn n_queues(&self) -> usize {
        1
    }

    fn queue_of(&self, _worker: usize) -> usize {
        0
    }

    fn remaining_in(&self, _queue: usize) -> usize {
        self.part.remaining()
    }
}

// ---------------------------------------------------------------------------
// centralized, atomic (§5 future work; ablation)
// ---------------------------------------------------------------------------

/// Chunk boundaries are precomputed once (every scheme's sequence is
/// deterministic given its seed), then served by a single `fetch_add` —
/// no lock, no serialization beyond cache-line ping-pong on the counter.
pub struct CentralAtomic {
    chunks: Vec<TaskRange>,
    head: AtomicUsize,
    total: usize,
}

impl CentralAtomic {
    pub fn new(
        scheme: Scheme,
        total: usize,
        workers: usize,
        opts: &PartitionerOptions,
    ) -> Self {
        let chunks =
            Partitioner::new(scheme, 0, total, workers, opts).chunk_sequence();
        CentralAtomic { chunks, head: AtomicUsize::new(0), total }
    }

    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }
}

impl TaskSource for CentralAtomic {
    fn pull_local(&self, _worker: usize) -> Option<Pull> {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        self.chunks
            .get(i)
            .map(|&task| Pull { task, queue: 0, stolen: false })
    }

    fn pull_from(&self, _queue: usize, worker: usize) -> Option<Pull> {
        self.pull_local(worker)
    }

    fn n_queues(&self) -> usize {
        1
    }

    fn queue_of(&self, _worker: usize) -> usize {
        0
    }

    fn remaining_in(&self, _queue: usize) -> usize {
        let served: usize = self
            .chunks
            .iter()
            .take(self.head.load(Ordering::Relaxed).min(self.chunks.len()))
            .map(|c| c.len())
            .sum();
        self.total - served
    }
}

// ---------------------------------------------------------------------------
// multi-queue (PERCORE / PERCPU) with per-queue partitioners
// ---------------------------------------------------------------------------

/// The two multi-queue layouts differ in how tasks reach the queues —
/// a distinction the paper leans on to explain Figs. 8-9:
///
/// - **PERCORE** (`Dealt`): *no pre-partitioning*. The chunk sequence is
///   generated globally by the scheme (exactly as the centralized
///   layout would) and dealt round-robin into one queue per worker;
///   workers obtain tasks "in arbitrary order" with no block locality —
///   which is why STATIC under PERCORE performs like STATIC under the
///   centralized queue (§4, Fig. 8a discussion).
/// - **PERCPU** (`Blocked`): the input is pre-partitioned into one
///   contiguous block per NUMA domain, each with its own partitioner —
///   the improved spatial locality the paper credits for STATIC's win
///   in Figs. 8b/9b. Chunk formulas still use the *global* worker count
///   P, so MFSC's granularity shrinks by 1/#CPU (the contention effect
///   of Fig. 8b).
///
/// In both layouts a thief's steal granularity follows the chosen
/// scheme (C.2): dealt chunks were generated by it, and block
/// partitioners compute it on demand.
enum MultiQueueKind {
    Dealt { queues: Vec<Mutex<std::collections::VecDeque<TaskRange>>> },
    Blocked { queues: Vec<Partitioner> },
}

use std::sync::Mutex;

/// Per-core or per-NUMA-group queues (see [`MultiQueueKind`]).
pub struct MultiQueue {
    kind: MultiQueueKind,
    /// worker -> owned queue index.
    owner: Vec<usize>,
    /// queue -> NUMA domain it is homed on.
    socket: Vec<usize>,
    /// Whether queue blocks correspond to contiguous input blocks
    /// (execution locality accounting in the DES).
    pub pre_partitioned: bool,
}

impl MultiQueue {
    pub fn new(
        layout: QueueLayout,
        scheme: Scheme,
        total: usize,
        topo: &Topology,
        opts: &PartitionerOptions,
    ) -> Self {
        let workers = topo.n_cores();
        match layout {
            QueueLayout::PerCore => {
                // global chunk sequence, dealt round-robin
                let chunks =
                    Partitioner::new(scheme, 0, total, workers, opts)
                        .chunk_sequence();
                let mut queues: Vec<std::collections::VecDeque<TaskRange>> =
                    (0..workers).map(|_| Default::default()).collect();
                for (i, chunk) in chunks.into_iter().enumerate() {
                    queues[i % workers].push_back(chunk);
                }
                MultiQueue {
                    kind: MultiQueueKind::Dealt {
                        queues: queues.into_iter().map(Mutex::new).collect(),
                    },
                    owner: (0..workers).collect(),
                    socket: (0..workers).map(|w| topo.socket_of(w)).collect(),
                    pre_partitioned: false,
                }
            }
            QueueLayout::PerGroup => {
                let n_queues = topo.sockets;
                let base_size = total / n_queues;
                let extra = total % n_queues;
                let mut queues = Vec::with_capacity(n_queues);
                let mut start = 0;
                for q in 0..n_queues {
                    let len = base_size + usize::from(q < extra);
                    queues.push(Partitioner::new(
                        scheme,
                        start,
                        len,
                        workers,
                        &PartitionerOptions {
                            seed: opts.seed.wrapping_add(q as u64),
                            ..opts.clone()
                        },
                    ));
                    start += len;
                }
                debug_assert_eq!(start, total);
                MultiQueue {
                    kind: MultiQueueKind::Blocked { queues },
                    owner: (0..workers).map(|w| topo.socket_of(w)).collect(),
                    socket: (0..n_queues).collect(),
                    pre_partitioned: true,
                }
            }
            QueueLayout::Centralized { .. } => {
                panic!("MultiQueue requires a multi-queue layout")
            }
        }
    }

    /// NUMA domain a queue is homed on (victim selection).
    pub fn socket_of_queue(&self, queue: usize) -> usize {
        self.socket[queue]
    }

    fn pop(&self, queue: usize) -> Option<TaskRange> {
        match &self.kind {
            MultiQueueKind::Dealt { queues } => {
                queues[queue].lock().unwrap().pop_front()
            }
            MultiQueueKind::Blocked { queues } => queues[queue].next_chunk(),
        }
    }
}

impl TaskSource for MultiQueue {
    fn pull_local(&self, worker: usize) -> Option<Pull> {
        let q = self.owner[worker];
        self.pop(q).map(|task| Pull { task, queue: q, stolen: false })
    }

    fn pull_from(&self, queue: usize, worker: usize) -> Option<Pull> {
        let stolen = self.owner[worker] != queue;
        self.pop(queue).map(|task| Pull { task, queue, stolen })
    }

    fn n_queues(&self) -> usize {
        self.socket.len()
    }

    fn queue_of(&self, worker: usize) -> usize {
        self.owner[worker]
    }

    fn remaining_in(&self, queue: usize) -> usize {
        match &self.kind {
            MultiQueueKind::Dealt { queues } => queues[queue]
                .lock()
                .unwrap()
                .iter()
                .map(|t| t.len())
                .sum(),
            MultiQueueKind::Blocked { queues } => queues[queue].remaining(),
        }
    }
}

/// Build the task source for a layout (the Fig. 4 queue system).
pub fn build_source(
    layout: QueueLayout,
    scheme: Scheme,
    total: usize,
    topo: &Topology,
    opts: &PartitionerOptions,
) -> Box<dyn TaskSource> {
    match layout {
        QueueLayout::Centralized { atomic: false } => {
            Box::new(CentralLocked::new(scheme, total, topo.n_cores(), opts))
        }
        QueueLayout::Centralized { atomic: true } => {
            Box::new(CentralAtomic::new(scheme, total, topo.n_cores(), opts))
        }
        QueueLayout::PerGroup | QueueLayout::PerCore => {
            Box::new(MultiQueue::new(layout, scheme, total, topo, opts))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn opts() -> PartitionerOptions {
        PartitionerOptions::default()
    }

    fn drain_all(src: &dyn TaskSource) -> Vec<TaskRange> {
        let mut out = Vec::new();
        for q in 0..src.n_queues() {
            while let Some(p) = src.pull_from(q, 0) {
                out.push(p.task);
            }
        }
        out.sort_by_key(|t| t.start);
        out
    }

    fn assert_partition(chunks: &[TaskRange], n: usize) {
        let mut cursor = 0;
        for c in chunks {
            assert_eq!(c.start, cursor, "gap/overlap at {cursor}");
            cursor = c.end;
        }
        assert_eq!(cursor, n);
    }

    #[test]
    fn central_locked_partitions() {
        let topo = Topology::broadwell20();
        let src = CentralLocked::new(Scheme::Gss, 1000, topo.n_cores(), &opts());
        assert_eq!(src.n_queues(), 1);
        assert_partition(&drain_all(&src), 1000);
    }

    #[test]
    fn central_atomic_matches_locked_sequence() {
        let locked = CentralLocked::new(Scheme::Tss, 5000, 8, &opts());
        let atomic = CentralAtomic::new(Scheme::Tss, 5000, 8, &opts());
        let a = drain_all(&locked);
        let b = drain_all(&atomic);
        assert_eq!(a, b, "atomic variant must serve the same chunks");
    }

    #[test]
    fn central_atomic_remaining_tracks() {
        let src = CentralAtomic::new(Scheme::Static, 100, 4, &opts());
        assert_eq!(src.remaining_in(0), 100);
        src.pull_local(0).unwrap();
        assert_eq!(src.remaining_in(0), 75);
    }

    #[test]
    fn percore_deals_global_sequence_round_robin() {
        let topo = Topology::broadwell20();
        let mq =
            MultiQueue::new(QueueLayout::PerCore, Scheme::Static, 1000, &topo, &opts());
        assert_eq!(mq.n_queues(), 20);
        assert!(!mq.pre_partitioned);
        // STATIC generates exactly P=20 chunks globally; dealt round-
        // robin, each queue holds one chunk of 50.
        for q in 0..20 {
            assert_eq!(mq.remaining_in(q), 50, "queue {q}");
        }
        assert_partition(&drain_all(&mq), 1000);
    }

    #[test]
    fn percore_chunks_match_central_sequence() {
        // No pre-partitioning: the dealt chunks are exactly the chunk
        // sequence the centralized queue would serve (§4, Fig. 8a).
        let topo = Topology::broadwell20();
        let central =
            CentralLocked::new(Scheme::Gss, 5000, topo.n_cores(), &opts());
        let percore = MultiQueue::new(
            QueueLayout::PerCore,
            Scheme::Gss,
            5000,
            &topo,
            &opts(),
        );
        let mut a = drain_all(&central);
        let mut b = drain_all(&percore);
        a.sort_by_key(|t| t.start);
        b.sort_by_key(|t| t.start);
        assert_eq!(a, b);
    }

    #[test]
    fn pergroup_one_queue_per_socket() {
        let topo = Topology::broadwell20();
        let mq =
            MultiQueue::new(QueueLayout::PerGroup, Scheme::Gss, 997, &topo, &opts());
        assert_eq!(mq.n_queues(), 2);
        assert!(mq.pre_partitioned);
        assert_eq!(mq.queue_of(0), 0);
        assert_eq!(mq.queue_of(19), 1);
        assert_eq!(mq.socket_of_queue(1), 1);
        assert_partition(&drain_all(&mq), 997);
    }

    #[test]
    fn pergroup_blocks_are_contiguous_per_socket() {
        let topo = Topology::broadwell20();
        let mq = MultiQueue::new(
            QueueLayout::PerGroup,
            Scheme::Static,
            1000,
            &topo,
            &opts(),
        );
        // queue 0 serves only rows < 500, queue 1 only rows >= 500
        let mut q0 = Vec::new();
        while let Some(p) = mq.pull_from(0, 0) {
            q0.push(p.task);
        }
        assert!(q0.iter().all(|t| t.end <= 500), "{q0:?}");
        let mut q1 = Vec::new();
        while let Some(p) = mq.pull_from(1, 19) {
            q1.push(p.task);
        }
        assert!(q1.iter().all(|t| t.start >= 500), "{q1:?}");
    }

    #[test]
    fn pergroup_blocks_halve_mfsc_granularity() {
        // The Fig. 8b effect: pre-partitioning a block per CPU shrinks
        // MFSC's chunk size (computed over N/#CPU items), raising queue
        // traffic.
        let topo = Topology::broadwell20();
        let central =
            CentralLocked::new(Scheme::Mfsc, 100_000, topo.n_cores(), &opts());
        let grouped =
            MultiQueue::new(QueueLayout::PerGroup, Scheme::Mfsc, 100_000, &topo, &opts());
        let c0 = central.pull_local(0).unwrap().task.len();
        let g0 = grouped.pull_local(0).unwrap().task.len();
        assert!(
            g0 < c0,
            "per-group MFSC chunk {g0} should be smaller than central {c0}"
        );
    }

    #[test]
    fn steal_marks_stolen() {
        let topo = Topology::broadwell20();
        let mq =
            MultiQueue::new(QueueLayout::PerCore, Scheme::Static, 1000, &topo, &opts());
        let own = mq.pull_local(3).unwrap();
        assert!(!own.stolen);
        let theft = mq.pull_from(7, 3).unwrap();
        assert!(theft.stolen);
        assert_eq!(theft.queue, 7);
    }

    #[test]
    fn remaining_total_and_exhaustion() {
        let topo = Topology::broadwell20();
        let src = build_source(
            QueueLayout::PerGroup,
            Scheme::Static,
            1_000,
            &topo,
            &opts(),
        );
        assert_eq!(src.remaining_total(), 1_000);
        assert!(!src.is_exhausted());
        let _ = drain_all(&*src);
        assert_eq!(src.remaining_total(), 0);
        assert!(src.is_exhausted(), "drained source must stay exhausted");
    }

    #[test]
    fn layout_parse_roundtrip() {
        for (s, l) in [
            ("central", QueueLayout::Centralized { atomic: false }),
            ("atomic", QueueLayout::Centralized { atomic: true }),
            ("percpu", QueueLayout::PerGroup),
            ("percore", QueueLayout::PerCore),
        ] {
            assert_eq!(QueueLayout::parse(s), Some(l));
        }
        assert_eq!(QueueLayout::parse("bogus"), None);
    }

    #[test]
    fn prop_every_layout_partitions_exactly() {
        prop::check("all layouts partition", 60, |rng| {
            let topo = if rng.below(2) == 0 {
                Topology::broadwell20()
            } else {
                Topology::cascadelake56()
            };
            let layout = *rng.choose(&[
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ]);
            let scheme = *rng.choose(&Scheme::ALL);
            let n = rng.range(1, 30_000) as usize;
            let o = PartitionerOptions { seed: rng.next_u64(), ..opts() };
            let src = build_source(layout, scheme, n, &topo, &o);
            let mut chunks = Vec::new();
            for q in 0..src.n_queues() {
                while let Some(p) = src.pull_from(q, 0) {
                    chunks.push(p.task);
                }
            }
            chunks.sort_by_key(|t| t.start);
            let mut cursor = 0;
            for c in &chunks {
                prop::ensure(
                    c.start == cursor && !c.is_empty(),
                    format!("{layout:?}/{scheme:?}: bad chunk {c:?} at {cursor}"),
                )?;
                cursor = c.end;
            }
            prop::ensure(cursor == n, format!("covered {cursor}/{n}"))
        });
    }
}
