//! Run the paper's DaphneDSL listings verbatim through the subset
//! interpreter; each vectorized operator is scheduled by DaphneSched.
//!
//! ```sh
//! cargo run --release --example dsl_pipeline
//! ```

use std::collections::BTreeMap;

use daphne_sched::config::SchedConfig;
use daphne_sched::dsl;
use daphne_sched::sched::Scheme;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn params(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn main() {
    let vee = Vee::new(
        Topology::host(),
        SchedConfig::default().with_scheme(Scheme::Mfsc),
    );

    println!("== Listing 1: connected components ==");
    let out = dsl::run_script(
        dsl::LISTING_1_CC,
        &params(&[("f", "synthetic:amazon?nodes=20000&seed=5")]),
        &vee,
    )
    .unwrap();
    println!(
        "  converged: diff={} iter={} ({} scheduled operators, {:.4}s)",
        out.num("diff").unwrap(),
        out.num("iter").unwrap(),
        out.reports.len(),
        out.scheduled_time()
    );

    println!("== Listing 2: linear regression ==");
    let out = dsl::run_script(
        dsl::LISTING_2_LINREG,
        &params(&[("numRows", "20000"), ("numCols", "17")]),
        &vee,
    )
    .unwrap();
    let beta = out.mat("beta").unwrap();
    println!(
        "  beta: {} coefficients, head = {:?} ({} scheduled operators, {:.4}s)",
        beta.rows,
        &beta.data[..4.min(beta.data.len())],
        out.reports.len(),
        out.scheduled_time()
    );
    for (name, report) in out.reports.iter().take(6) {
        println!("    {name:<14} {}", report.row());
    }
}
