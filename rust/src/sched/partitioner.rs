//! Work partitioning: the eleven task-partitioning schemes of §3,
//! mirroring DAPHNE's `getNextChunk` interface.
//!
//! Each scheme computes the size of the next task from `(total items N,
//! workers P, items remaining R, chunks handed out so far)`. The
//! formulas follow the original publications (citations per variant) in
//! the profiling-free forms used by DAPHNE/LB4OMP — FAC2 and MFSC are
//! the practical implementations of FAC and FSC that need no prior
//! profiling data.
//!
//! The partitioner is shared state: the centralized layout has all
//! workers pulling from one instance; multi-queue layouts give every
//! queue its own instance over its block (so *stolen* chunks also follow
//! the scheme — contribution C.2).

use std::sync::Mutex;

use super::task::TaskRange;
use crate::util::Rng;

/// The eleven supported partitioning schemes (paper §3, Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// One contiguous chunk of `ceil(N/P)` per worker (DAPHNE default)
    /// \[Li et al., ICPP'93\].
    Static,
    /// Self-scheduling: one item at a time \[Tang & Yew, ICPP'86\].
    Ss,
    /// Modified fixed-size chunking: FSC \[Kruskal & Weiss, TSE'85\]
    /// without profiling inputs (LB4OMP's practical variant).
    Mfsc,
    /// Guided self-scheduling: `ceil(R/P)` \[Polychronopoulos & Kuck,
    /// TC'87\].
    Gss,
    /// Trapezoid self-scheduling: linearly decreasing chunks
    /// \[Tzen & Ni, TPDS'93\].
    Tss,
    /// Factoring, practical x=2 variant: batches of P chunks sized
    /// `ceil(R/(2P))` \[Flynn Hummel et al., CACM'92\].
    Fac2,
    /// Trapezoid factoring self-scheduling: TSS chunk averaged over a
    /// batch of P \[Chronopoulos et al., Cluster'01\].
    Tfss,
    /// Fixed-increase self-scheduling \[Philip & Das, PDCS'97\].
    Fiss,
    /// Variable-increase self-scheduling \[Philip & Das, PDCS'97\].
    Viss,
    /// Performance loop-based scheduling: a static fraction (SWR) first,
    /// GSS on the rest \[Shih et al., J. Supercomputing'07\].
    Pls,
    /// Probabilistic self-scheduling: `ceil(R/(1.5·E[active workers]))`
    /// \[Girkar et al., Euro-Par'06\].
    Pss,
}

impl Scheme {
    /// All schemes, in the order the paper's figures list them.
    pub const ALL: [Scheme; 11] = [
        Scheme::Static,
        Scheme::Ss,
        Scheme::Mfsc,
        Scheme::Gss,
        Scheme::Tss,
        Scheme::Fac2,
        Scheme::Tfss,
        Scheme::Fiss,
        Scheme::Viss,
        Scheme::Pls,
        Scheme::Pss,
    ];

    /// The ten schemes shown in Figures 7-10 (SS is omitted there: its
    /// execution time "explodes" under central-queue contention).
    pub const FIGURES: [Scheme; 10] = [
        Scheme::Static,
        Scheme::Mfsc,
        Scheme::Gss,
        Scheme::Tss,
        Scheme::Fac2,
        Scheme::Tfss,
        Scheme::Fiss,
        Scheme::Viss,
        Scheme::Pls,
        Scheme::Pss,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Static => "STATIC",
            Scheme::Ss => "SS",
            Scheme::Mfsc => "MFSC",
            Scheme::Gss => "GSS",
            Scheme::Tss => "TSS",
            Scheme::Fac2 => "FAC2",
            Scheme::Tfss => "TFSS",
            Scheme::Fiss => "FISS",
            Scheme::Viss => "VISS",
            Scheme::Pls => "PLS",
            Scheme::Pss => "PSS",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_uppercase().as_str() {
            "STATIC" => Some(Scheme::Static),
            "SS" => Some(Scheme::Ss),
            "MFSC" | "FSC" => Some(Scheme::Mfsc),
            "GSS" => Some(Scheme::Gss),
            "TSS" => Some(Scheme::Tss),
            "FAC2" | "FAC" => Some(Scheme::Fac2),
            "TFSS" => Some(Scheme::Tfss),
            "FISS" => Some(Scheme::Fiss),
            "VISS" => Some(Scheme::Viss),
            "PLS" => Some(Scheme::Pls),
            "PSS" => Some(Scheme::Pss),
            _ => None,
        }
    }

    /// Whether every chunk has the same size (enables the lock-free
    /// `fetch_add` fast path in the atomic central queue).
    pub fn fixed_chunk(&self) -> bool {
        matches!(self, Scheme::Static | Scheme::Ss | Scheme::Mfsc)
    }
}

/// Extension point (paper §3 "Extendability"): user-defined schemes
/// implement this and plug in via [`Partitioner::custom`]. `next_size`
/// is DAPHNE's `getNextChunk`.
pub trait ChunkCalc: Send {
    /// Size of the next chunk given items remaining and chunks issued.
    /// Must be >= 1 whenever `remaining > 0`; the partitioner clamps to
    /// `remaining`.
    fn next_size(&mut self, ctx: &ChunkCtx) -> usize;
}

/// Inputs available to a chunk calculation.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCtx {
    /// Total items N this partitioner started with.
    pub total: usize,
    /// Workers P sharing this partitioner.
    pub workers: usize,
    /// Items not yet handed out.
    pub remaining: usize,
    /// Chunks handed out so far.
    pub issued: usize,
}

/// Tuning knobs (defaults match the common literature choices).
#[derive(Debug, Clone)]
pub struct PartitionerOptions {
    /// FISS/VISS stage count B; `None` = `ceil(log2 P) + 1`.
    pub stages: Option<usize>,
    /// PLS static workload ratio.
    pub pls_swr: f64,
    /// Seed for PSS's probabilistic estimate.
    pub seed: u64,
}

impl Default for PartitionerOptions {
    fn default() -> Self {
        PartitionerOptions { stages: None, pls_swr: 0.5, seed: 0xDA9E }
    }
}

// ---------------------------------------------------------------------------
// scheme state machines
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum SchemeState {
    /// Fixed chunk size computed at construction (STATIC, SS, MFSC).
    Fixed { chunk: usize },
    Gss,
    Tss {
        /// Current chunk size (starts at ceil(N/2P)).
        chunk: f64,
        /// Linear decrement between consecutive chunks.
        delta: f64,
    },
    Fac2 {
        /// Chunk size for the current batch.
        chunk: usize,
        /// Chunks left in the current batch.
        left_in_batch: usize,
    },
    Tfss {
        chunk: f64,
        delta: f64,
        batch_chunk: usize,
        left_in_batch: usize,
    },
    FissViss {
        /// Current per-stage chunk size.
        chunk: f64,
        /// Additive increment applied at each stage boundary.
        increment: f64,
        /// FISS keeps the increment fixed; VISS halves it per stage.
        halve: bool,
        /// Chunks left before the next stage boundary.
        left_in_stage: usize,
    },
    Pls {
        /// Items in the static region still to hand out.
        static_left: usize,
        /// Chunk size within the static region.
        static_chunk: usize,
    },
    Pss { rng: Rng },
    Custom(Box<dyn ChunkCalc>),
}

impl std::fmt::Debug for Box<dyn ChunkCalc> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "<custom chunk calc>")
    }
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b.max(1))
}

/// MFSC: LB4OMP's profiling-free fixed chunk,
/// `ceil(2N / (P * log2(2N/P)))` — the FSC optimum with the overhead/
/// variability ratio folded into the log term.
fn mfsc_chunk(total: usize, workers: usize) -> usize {
    if total == 0 {
        return 1;
    }
    let n = total as f64;
    let p = workers.max(1) as f64;
    let l = (2.0 * n / p).log2().max(1.0);
    (2.0 * n / (p * l)).ceil().max(1.0) as usize
}

impl SchemeState {
    fn new(scheme: Scheme, total: usize, workers: usize, opts: &PartitionerOptions) -> Self {
        let p = workers.max(1);
        match scheme {
            Scheme::Static => SchemeState::Fixed { chunk: ceil_div(total, p) },
            Scheme::Ss => SchemeState::Fixed { chunk: 1 },
            Scheme::Mfsc => SchemeState::Fixed { chunk: mfsc_chunk(total, p) },
            Scheme::Gss => SchemeState::Gss,
            Scheme::Tss | Scheme::Tfss => {
                // Tzen & Ni: first = ceil(N/2P), last = 1,
                // C = ceil(2N/(first+last)), delta = (first-last)/(C-1).
                let first = ceil_div(total, 2 * p) as f64;
                let last = 1.0;
                let c = ((2.0 * total as f64) / (first + last)).ceil().max(2.0);
                let delta = (first - last) / (c - 1.0);
                if scheme == Scheme::Tss {
                    SchemeState::Tss { chunk: first, delta }
                } else {
                    SchemeState::Tfss {
                        chunk: first,
                        delta,
                        batch_chunk: 0,
                        left_in_batch: 0,
                    }
                }
            }
            Scheme::Fac2 => SchemeState::Fac2 { chunk: 0, left_in_batch: 0 },
            Scheme::Fiss | Scheme::Viss => {
                // Philip & Das: B stages; chunk_0 = N/((2+B)P); FISS bumps
                // by a fixed increment so that sum(stages) covers N.
                let b = opts
                    .stages
                    .unwrap_or_else(|| (p as f64).log2().ceil() as usize + 1)
                    .max(2);
                let chunk0 = (total as f64 / ((2 + b) as f64 * p as f64)).max(1.0);
                let bump = if b > 1 {
                    (2.0 * total as f64 * (1.0 - b as f64 / (2.0 + b as f64)))
                        / (p as f64 * b as f64 * (b as f64 - 1.0))
                } else {
                    0.0
                };
                SchemeState::FissViss {
                    chunk: chunk0,
                    increment: bump.max(0.0),
                    halve: scheme == Scheme::Viss,
                    left_in_stage: p,
                }
            }
            Scheme::Pls => {
                let static_items = (total as f64 * opts.pls_swr) as usize;
                SchemeState::Pls {
                    static_left: static_items,
                    static_chunk: ceil_div(static_items, p).max(1),
                }
            }
            Scheme::Pss => SchemeState::Pss { rng: Rng::new(opts.seed) },
        }
    }

    fn next_size(&mut self, ctx: &ChunkCtx) -> usize {
        let p = ctx.workers.max(1);
        match self {
            SchemeState::Fixed { chunk } => *chunk,
            SchemeState::Gss => ceil_div(ctx.remaining, p),
            SchemeState::Tss { chunk, delta } => {
                let size = chunk.round().max(1.0) as usize;
                *chunk = (*chunk - *delta).max(1.0);
                size
            }
            SchemeState::Fac2 { chunk, left_in_batch } => {
                if *left_in_batch == 0 {
                    // new batch: half the remaining, split across P chunks
                    *chunk = ceil_div(ceil_div(ctx.remaining, 2), p).max(1);
                    *left_in_batch = p;
                }
                *left_in_batch -= 1;
                *chunk
            }
            SchemeState::Tfss { chunk, delta, batch_chunk, left_in_batch } => {
                if *left_in_batch == 0 {
                    // batch chunk = mean of the next P trapezoid chunks
                    // = chunk - delta*(P-1)/2, held constant for P takes
                    let mean = *chunk - *delta * (p as f64 - 1.0) / 2.0;
                    *batch_chunk = mean.round().max(1.0) as usize;
                    *chunk = (*chunk - *delta * p as f64).max(1.0);
                    *left_in_batch = p;
                }
                *left_in_batch -= 1;
                *batch_chunk
            }
            SchemeState::FissViss { chunk, increment, halve, left_in_stage } => {
                if *left_in_stage == 0 {
                    *chunk += *increment;
                    if *halve {
                        *increment /= 2.0;
                    }
                    *left_in_stage = p;
                }
                *left_in_stage -= 1;
                chunk.round().max(1.0) as usize
            }
            SchemeState::Pls { static_left, static_chunk } => {
                if *static_left > 0 {
                    let take = (*static_chunk).min(*static_left);
                    *static_left -= take;
                    take
                } else {
                    // dynamic region: GSS over what remains
                    ceil_div(ctx.remaining, p)
                }
            }
            SchemeState::Pss { rng } => {
                // Girkar et al.: chunk = ceil(R / (1.5 * E[active])) with
                // the active-worker estimate fluctuating near P (most of
                // the time most workers are busy): uniform over
                // [ceil(P/2), P]. Behaves like a jittered, slightly
                // finer GSS.
                let lo = p.div_ceil(2) as u64;
                let p_est = rng.range(lo, p as u64 + 1) as usize;
                ceil_div(ctx.remaining, (3 * p_est).div_ceil(2))
            }
            SchemeState::Custom(calc) => calc.next_size(ctx),
        }
    }
}

// ---------------------------------------------------------------------------
// partitioner
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Inner {
    state: SchemeState,
    /// Next item to hand out (within `[base, base + total)`).
    cursor: usize,
    issued: usize,
}

/// Thread-safe chunk generator over a contiguous block of work items
/// (`base .. base + total`). This is Fig. 4's task partitioner: both its
/// interface points — *Initialize/Update* ([`Partitioner::new`]) and *Get
/// Task* ([`Partitioner::next_chunk`]) — operate on shared state so any
/// worker (owner or thief) can pull the next task.
pub struct Partitioner {
    scheme_name: &'static str,
    base: usize,
    total: usize,
    workers: usize,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for Partitioner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Partitioner")
            .field("scheme", &self.scheme_name)
            .field("base", &self.base)
            .field("total", &self.total)
            .field("workers", &self.workers)
            .finish()
    }
}

impl Partitioner {
    /// Partition `total` items starting at global index `base` among
    /// `workers` pullers using `scheme`.
    pub fn new(
        scheme: Scheme,
        base: usize,
        total: usize,
        workers: usize,
        opts: &PartitionerOptions,
    ) -> Self {
        Partitioner {
            scheme_name: scheme.name(),
            base,
            total,
            workers,
            inner: Mutex::new(Inner {
                state: SchemeState::new(scheme, total, workers, opts),
                cursor: 0,
                issued: 0,
            }),
        }
    }

    /// Plug in a user-defined scheme (paper §3 "Extendability").
    pub fn custom(
        name: &'static str,
        base: usize,
        total: usize,
        workers: usize,
        calc: Box<dyn ChunkCalc>,
    ) -> Self {
        Partitioner {
            scheme_name: name,
            base,
            total,
            workers,
            inner: Mutex::new(Inner {
                state: SchemeState::Custom(calc),
                cursor: 0,
                issued: 0,
            }),
        }
    }

    pub fn scheme_name(&self) -> &'static str {
        self.scheme_name
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// First global item index of this partitioner's block.
    pub fn base(&self) -> usize {
        self.base
    }

    /// Items not yet handed out.
    pub fn remaining(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        self.total - inner.cursor
    }

    /// *Get Task*: the next variable-size task, or `None` when the block
    /// is exhausted.
    pub fn next_chunk(&self) -> Option<TaskRange> {
        let mut inner = self.inner.lock().unwrap();
        let remaining = self.total - inner.cursor;
        if remaining == 0 {
            return None;
        }
        let ctx = ChunkCtx {
            total: self.total,
            workers: self.workers,
            remaining,
            issued: inner.issued,
        };
        let size = inner.state.next_size(&ctx).clamp(1, remaining);
        let start = self.base + inner.cursor;
        inner.cursor += size;
        inner.issued += 1;
        Some(TaskRange::new(start, start + size))
    }

    /// Drain the full chunk sequence (tests, figures, and the atomic
    /// central queue's precomputation).
    pub fn chunk_sequence(&self) -> Vec<TaskRange> {
        let mut v = Vec::new();
        while let Some(c) = self.next_chunk() {
            v.push(c);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn sizes(scheme: Scheme, n: usize, p: usize) -> Vec<usize> {
        Partitioner::new(scheme, 0, n, p, &PartitionerOptions::default())
            .chunk_sequence()
            .iter()
            .map(|c| c.len())
            .collect()
    }

    #[test]
    fn static_one_chunk_per_worker() {
        let s = sizes(Scheme::Static, 1000, 8);
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|&c| c == 125));
    }

    #[test]
    fn static_uneven_total() {
        let s = sizes(Scheme::Static, 1001, 8);
        assert_eq!(s.iter().sum::<usize>(), 1001);
        assert_eq!(s.len(), 8);
        assert_eq!(s[0], 126); // ceil(1001/8)
        assert_eq!(*s.last().unwrap(), 1001 - 7 * 126);
    }

    #[test]
    fn ss_unit_chunks() {
        let s = sizes(Scheme::Ss, 100, 8);
        assert_eq!(s.len(), 100);
        assert!(s.iter().all(|&c| c == 1));
    }

    #[test]
    fn mfsc_fixed_moderate_chunks() {
        let s = sizes(Scheme::Mfsc, 100_000, 20);
        let c0 = s[0];
        // fixed size except the tail chunk
        assert!(s[..s.len() - 1].iter().all(|&c| c == c0));
        // far fewer chunks than SS, far more than STATIC
        assert!(s.len() > 20 && s.len() < 100_000 / 20, "len={}", s.len());
    }

    #[test]
    fn gss_decreasing_then_unit() {
        let s = sizes(Scheme::Gss, 1000, 4);
        assert_eq!(s[0], 250); // ceil(1000/4)
        assert!(s.windows(2).all(|w| w[1] <= w[0]));
        assert_eq!(*s.last().unwrap(), 1);
    }

    #[test]
    fn tss_linear_decrease() {
        let s = sizes(Scheme::Tss, 10_000, 10);
        assert_eq!(s[0], 500); // ceil(N/2P)
        assert!(s.windows(2).all(|w| w[1] <= w[0]));
        // delta should be roughly constant (rounding jitter ±1); the
        // final chunk absorbs the clamp-to-remaining tail, so skip it.
        let deltas: Vec<i64> =
            s.windows(2).map(|w| w[0] as i64 - w[1] as i64).collect();
        let body = &deltas[..deltas.len() - 1];
        let max_d = *body.iter().max().unwrap();
        let min_d = *body.iter().min().unwrap();
        assert!(max_d - min_d <= 2, "not linear: {deltas:?}");
    }

    #[test]
    fn fac2_batches_of_p_halving() {
        let s = sizes(Scheme::Fac2, 1600, 4);
        // first batch: ceil(800/4) = 200 four times, then 100 four times...
        assert_eq!(&s[..4], &[200, 200, 200, 200]);
        assert_eq!(&s[4..8], &[100, 100, 100, 100]);
        assert_eq!(&s[8..12], &[50, 50, 50, 50]);
    }

    #[test]
    fn tfss_batches_follow_trapezoid_means() {
        let s = sizes(Scheme::Tfss, 10_000, 10);
        // constant within each batch of P
        for batch in s.chunks(10).take(3) {
            if batch.len() == 10 {
                assert!(batch.iter().all(|&c| c == batch[0]), "{batch:?}");
            }
        }
        // decreasing across batches
        assert!(s[0] > s[10] && s[10] > s[20]);
    }

    #[test]
    fn fiss_increasing_stages() {
        let s = sizes(Scheme::Fiss, 10_000, 8);
        // constant within a stage of P chunks, increasing across stages
        assert!(s[..8].iter().all(|&c| c == s[0]));
        if s.len() > 16 {
            assert!(s[8] >= s[0], "{s:?}");
            assert!(s[16] >= s[8], "{s:?}");
        }
    }

    #[test]
    fn viss_increments_shrink() {
        let s = sizes(Scheme::Viss, 10_000, 8);
        if s.len() > 24 {
            let inc1 = s[8] as i64 - s[0] as i64;
            let inc2 = s[16] as i64 - s[8] as i64;
            assert!(inc2 <= inc1, "VISS increments must shrink: {s:?}");
        }
    }

    #[test]
    fn pls_static_then_dynamic() {
        let s = sizes(Scheme::Pls, 1000, 4);
        // first half static: 4 chunks of 125
        assert_eq!(&s[..4], &[125, 125, 125, 125]);
        // then GSS over the remaining 500
        assert_eq!(s[4], 125); // ceil(500/4)
        assert!(s[5] <= s[4]);
        assert_eq!(*s.last().unwrap(), 1);
    }

    #[test]
    fn pss_is_seeded_and_bounded() {
        let opts = PartitionerOptions { seed: 42, ..Default::default() };
        let a: Vec<usize> = Partitioner::new(Scheme::Pss, 0, 5000, 8, &opts)
            .chunk_sequence()
            .iter()
            .map(|c| c.len())
            .collect();
        let b: Vec<usize> = Partitioner::new(Scheme::Pss, 0, 5000, 8, &opts)
            .chunk_sequence()
            .iter()
            .map(|c| c.len())
            .collect();
        assert_eq!(a, b, "PSS must replay from its seed");
        // chunks never exceed GSS-with-1-active-worker bound: ceil(R/1.5)
        assert!(a[0] <= 5000);
    }

    #[test]
    fn base_offsets_propagate() {
        let p = Partitioner::new(
            Scheme::Gss,
            1000,
            100,
            4,
            &PartitionerOptions::default(),
        );
        let chunks = p.chunk_sequence();
        assert_eq!(chunks.first().unwrap().start, 1000);
        assert_eq!(chunks.last().unwrap().end, 1100);
    }

    #[test]
    fn custom_scheme_plugs_in() {
        struct Fives;
        impl ChunkCalc for Fives {
            fn next_size(&mut self, _: &ChunkCtx) -> usize {
                5
            }
        }
        let p = Partitioner::custom("FIVES", 0, 23, 4, Box::new(Fives));
        let s: Vec<usize> =
            p.chunk_sequence().iter().map(|c| c.len()).collect();
        assert_eq!(s, vec![5, 5, 5, 5, 3]);
        assert_eq!(p.scheme_name(), "FIVES");
    }

    #[test]
    fn mfsc_chunk_formula_sane() {
        // N=100k, P=20: chunk = 2N/(P*log2(2N/P)) = 10000/log2(10000) ~ 753
        let c = mfsc_chunk(100_000, 20);
        assert!((600..=900).contains(&c), "mfsc chunk {c}");
    }

    // ---------------- property tests (all schemes) ----------------

    #[test]
    fn prop_chunks_partition_exactly() {
        prop::check("chunks partition [0,N) exactly", 150, |rng| {
            let scheme = *rng.choose(&Scheme::ALL);
            let n = rng.range(1, 50_000) as usize;
            let p = rng.range(1, 64) as usize;
            let opts = PartitionerOptions {
                seed: rng.next_u64(),
                ..Default::default()
            };
            let chunks =
                Partitioner::new(scheme, 0, n, p, &opts).chunk_sequence();
            let mut cursor = 0;
            for c in &chunks {
                prop::ensure(
                    c.start == cursor,
                    format!("{scheme:?}: gap at {cursor} vs {c:?}"),
                )?;
                prop::ensure(
                    !c.is_empty(),
                    format!("{scheme:?}: empty chunk at {cursor}"),
                )?;
                cursor = c.end;
            }
            prop::ensure(
                cursor == n,
                format!("{scheme:?}: covered {cursor} of {n}"),
            )
        });
    }

    #[test]
    fn prop_chunk_count_reasonable() {
        // No scheme may issue more chunks than items, and every scheme
        // must terminate (guaranteed by clamp >= 1).
        prop::check("chunk count bounded by N", 100, |rng| {
            let scheme = *rng.choose(&Scheme::ALL);
            let n = rng.range(1, 10_000) as usize;
            let p = rng.range(1, 32) as usize;
            let opts = PartitionerOptions {
                seed: rng.next_u64(),
                ..Default::default()
            };
            let k =
                Partitioner::new(scheme, 0, n, p, &opts).chunk_sequence().len();
            prop::ensure(k <= n, format!("{scheme:?}: {k} chunks for {n}"))
        });
    }

    #[test]
    fn prop_concurrent_pulls_partition() {
        // Shared-state safety: chunks pulled from many threads still
        // partition the range exactly (centralized layout invariant).
        prop::check("concurrent pulls partition", 20, |rng| {
            let scheme = *rng.choose(&Scheme::ALL);
            let n = rng.range(1_000, 20_000) as usize;
            let p = 4;
            let opts = PartitionerOptions {
                seed: rng.next_u64(),
                ..Default::default()
            };
            let part =
                std::sync::Arc::new(Partitioner::new(scheme, 0, n, p, &opts));
            let mut handles = Vec::new();
            for _ in 0..p {
                let part = part.clone();
                handles.push(std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(c) = part.next_chunk() {
                        got.push(c);
                    }
                    got
                }));
            }
            let mut all: Vec<TaskRange> = handles
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .collect();
            all.sort_by_key(|c| c.start);
            let mut cursor = 0;
            for c in &all {
                prop::ensure(
                    c.start == cursor,
                    format!("{scheme:?}: overlap/gap at {cursor}"),
                )?;
                cursor = c.end;
            }
            prop::ensure(cursor == n, format!("{scheme:?}: covered {cursor}/{n}"))
        });
    }
}
