//! Recursive-descent parser for the DaphneDSL subset.
//!
//! Grammar (precedence low→high): `|` < `&` < comparisons < `+ -` <
//! `* /` < unary `-` < postfix (call args, `[ , cols ]` indexing).

use super::ast::{BinOp, Expr, Program, Stmt};
use super::lexer::Token;

pub fn parse(tokens: &[Token]) -> Result<Program, String> {
    let mut p = Parser { t: tokens, i: 0 };
    let mut stmts = Vec::new();
    while !p.done() {
        stmts.push(p.stmt()?);
    }
    Ok(Program { stmts })
}

struct Parser<'a> {
    t: &'a [Token],
    i: usize,
}

impl<'a> Parser<'a> {
    fn done(&self) -> bool {
        self.i >= self.t.len()
    }

    fn peek(&self) -> Option<&Token> {
        self.t.get(self.i)
    }

    fn next(&mut self) -> Option<&Token> {
        let t = self.t.get(self.i);
        self.i += 1;
        t
    }

    fn expect(&mut self, tok: &Token) -> Result<(), String> {
        match self.next() {
            Some(t) if t == tok => Ok(()),
            other => Err(format!("expected {tok:?}, found {other:?}")),
        }
    }

    fn stmt(&mut self) -> Result<Stmt, String> {
        match self.peek() {
            Some(Token::While) => {
                self.next();
                self.expect(&Token::LParen)?;
                let cond = self.expr()?;
                self.expect(&Token::RParen)?;
                self.expect(&Token::LBrace)?;
                let mut body = Vec::new();
                while self.peek() != Some(&Token::RBrace) {
                    if self.done() {
                        return Err("unterminated while body".into());
                    }
                    body.push(self.stmt()?);
                }
                self.expect(&Token::RBrace)?;
                Ok(Stmt::While(cond, body))
            }
            Some(Token::Ident(name))
                if self.t.get(self.i + 1) == Some(&Token::Assign) =>
            {
                let name = name.clone();
                self.i += 2;
                let value = self.expr()?;
                self.expect(&Token::Semi)?;
                Ok(Stmt::Assign(name, value))
            }
            _ => {
                let e = self.expr()?;
                self.expect(&Token::Semi)?;
                Ok(Stmt::Expr(e))
            }
        }
    }

    fn expr(&mut self) -> Result<Expr, String> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.and_expr()?;
        while self.peek() == Some(&Token::Or) {
            self.next();
            let rhs = self.and_expr()?;
            lhs = Expr::Binary(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.cmp_expr()?;
        while self.peek() == Some(&Token::And) {
            self.next();
            let rhs = self.cmp_expr()?;
            lhs = Expr::Binary(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, String> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Some(Token::Gt) => BinOp::Gt,
            Some(Token::Lt) => BinOp::Lt,
            Some(Token::Ge) => BinOp::Ge,
            Some(Token::Le) => BinOp::Le,
            Some(Token::Eq) => BinOp::Eq,
            Some(Token::Ne) => BinOp::Ne,
            _ => return Ok(lhs),
        };
        self.next();
        let rhs = self.add_expr()?;
        Ok(Expr::Binary(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Some(Token::Plus) => BinOp::Add,
                Some(Token::Minus) => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.next();
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, String> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Some(Token::Star) => BinOp::Mul,
                Some(Token::Slash) => BinOp::Div,
                _ => return Ok(lhs),
            };
            self.next();
            let rhs = self.unary_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn unary_expr(&mut self) -> Result<Expr, String> {
        if self.peek() == Some(&Token::Minus) {
            self.next();
            let e = self.unary_expr()?;
            return Ok(Expr::Neg(Box::new(e)));
        }
        self.postfix_expr()
    }

    fn postfix_expr(&mut self) -> Result<Expr, String> {
        let mut e = self.primary()?;
        loop {
            match self.peek() {
                // call only directly after an identifier
                Some(Token::LParen) if matches!(e, Expr::Var(_)) => {
                    let Expr::Var(name) = e else { unreachable!() };
                    self.next();
                    let mut args = Vec::new();
                    if self.peek() != Some(&Token::RParen) {
                        loop {
                            args.push(self.expr()?);
                            match self.next() {
                                Some(Token::Comma) => continue,
                                Some(Token::RParen) => break,
                                other => {
                                    return Err(format!(
                                        "expected ',' or ')' in call to \
                                         {name}, found {other:?}"
                                    ))
                                }
                            }
                        }
                    } else {
                        self.next();
                    }
                    e = Expr::Call(name, args);
                }
                // `X[, cols]` column indexing
                Some(Token::LBracket) => {
                    self.next();
                    self.expect(&Token::Comma)?;
                    let cols = self.expr()?;
                    self.expect(&Token::RBracket)?;
                    e = Expr::ColIndex(Box::new(e), Box::new(cols));
                }
                _ => return Ok(e),
            }
        }
    }

    fn primary(&mut self) -> Result<Expr, String> {
        match self.next() {
            Some(Token::Num(n)) => Ok(Expr::Num(*n)),
            Some(Token::Str(s)) => Ok(Expr::Str(s.clone())),
            Some(Token::Param(p)) => Ok(Expr::Param(p.clone())),
            Some(Token::Ident(name)) => Ok(Expr::Var(name.clone())),
            Some(Token::LParen) => {
                let e = self.expr()?;
                self.expect(&Token::RParen)?;
                Ok(e)
            }
            other => Err(format!("unexpected token {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::lexer::lex;

    fn parse_src(src: &str) -> Program {
        parse(&lex(src).unwrap()).unwrap()
    }

    #[test]
    fn parses_assignment_with_precedence() {
        let p = parse_src("x = 1 + 2 * 3;");
        let Stmt::Assign(name, Expr::Binary(BinOp::Add, _, rhs)) = &p.stmts[0]
        else {
            panic!("{p:?}");
        };
        assert_eq!(name, "x");
        assert!(matches!(**rhs, Expr::Binary(BinOp::Mul, _, _)));
    }

    #[test]
    fn parses_while_with_logical_and() {
        let p = parse_src("while (diff > 0 & iter <= maxi) { iter = iter + 1; }");
        let Stmt::While(cond, body) = &p.stmts[0] else { panic!() };
        assert!(matches!(cond, Expr::Binary(BinOp::And, _, _)));
        assert_eq!(body.len(), 1);
    }

    #[test]
    fn parses_column_indexing() {
        let p = parse_src("X = XY[, seq(0, 3, 1)];");
        let Stmt::Assign(_, Expr::ColIndex(target, cols)) = &p.stmts[0] else {
            panic!("{p:?}")
        };
        assert!(matches!(**target, Expr::Var(_)));
        assert!(matches!(**cols, Expr::Call(ref n, _) if n == "seq"));
    }

    #[test]
    fn parses_nested_calls_and_params() {
        let p = parse_src("u = max(rowMaxs(G * t(c)), c);");
        let Stmt::Assign(_, Expr::Call(name, args)) = &p.stmts[0] else {
            panic!()
        };
        assert_eq!(name, "max");
        assert_eq!(args.len(), 2);
        let p = parse_src("G = readMatrix($f);");
        let Stmt::Assign(_, Expr::Call(_, args)) = &p.stmts[0] else {
            panic!()
        };
        assert!(matches!(args[0], Expr::Param(ref s) if s == "f"));
    }

    #[test]
    fn parses_both_listings() {
        let p1 = parse_src(crate::dsl::LISTING_1_CC);
        assert!(p1.stmts.len() >= 6);
        assert!(p1.stmts.iter().any(|s| matches!(s, Stmt::While(_, _))));
        let p2 = parse_src(crate::dsl::LISTING_2_LINREG);
        assert_eq!(p2.stmts.len(), 12, "listing 2 has 12 statements");
    }

    #[test]
    fn unary_minus_binds_tight() {
        let p = parse_src("x = rand(3, 3, 0.0, 1.0, 1, -1);");
        let Stmt::Assign(_, Expr::Call(_, args)) = &p.stmts[0] else {
            panic!()
        };
        assert!(matches!(args[5], Expr::Neg(_)));
    }

    #[test]
    fn reports_errors() {
        assert!(parse(&lex("x = ;").unwrap()).is_err());
        assert!(parse(&lex("while (1) { x = 1;").unwrap()).is_err());
        assert!(parse(&lex("f(1, 2").unwrap()).is_err());
    }
}
