//! Quickstart: submit jobs to DaphneSched's persistent executor.
//!
//! Worker threads are spawned **once** (one per topology place) and
//! parked between jobs; work is submitted as jobs, each carrying its own
//! scheduling configuration — so one resident pool runs the DAPHNE
//! default (STATIC, centralized queue) and a work-stealing configuration
//! back-to-back, or even concurrently.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use daphne_sched::apps::cc;
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::sched::{Executor, JobSpec, QueueLayout, Scheme, VictimStrategy};
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn main() {
    // 1. the raw job-submission API ------------------------------------
    // One persistent pool on this host; STATIC is the executor default.
    let exec = Executor::host(SchedConfig::default());
    println!(
        "executor: {} resident workers on '{}'",
        exec.n_workers(),
        exec.topology().name
    );

    // a borrowed-body job: partition 1M items, run, wait for the report
    let report = exec.run(JobSpec::new(1_000_000).named("warmup"), |_w, range| {
        std::hint::black_box(range.len());
    });
    println!("  warmup           {}", report.row());

    // a job with a per-job scheduling override: GSS chunks dealt into
    // per-core queues with randomized NUMA-aware stealing — same pool.
    let stealing = SchedConfig::default()
        .with_scheme(Scheme::Gss)
        .with_layout(QueueLayout::PerCore)
        .with_victim(VictimStrategy::RndPri);
    let report = exec.run(
        JobSpec::new(1_000_000).named("gss").with_config(stealing),
        |_w, range| {
            std::hint::black_box(range.len());
        },
    );
    println!("  per-job override {}", report.row());

    // two jobs in flight at once, multiplexed over the same workers
    exec.scope(|s| {
        let a = s.submit(JobSpec::new(500_000).named("tenant-a"), |_w, r| {
            std::hint::black_box(r.len());
        });
        let b = s.submit(JobSpec::new(500_000).named("tenant-b"), |_w, r| {
            std::hint::black_box(r.len());
        });
        println!("  concurrent a     {}", a.wait().row());
        println!("  concurrent b     {}", b.wait().row());
    });
    println!(
        "  {} jobs completed, 0 thread respawns\n",
        exec.jobs_completed()
    );

    // 2. a real workload through the VEE -------------------------------
    // connected components over a co-purchase-like graph; the engine
    // fronts one persistent executor, every propagate iteration is a job
    let graph = amazon_like(&SnapGraph::small(20_000, 7)).symmetrize();
    println!(
        "graph: {} nodes, {} edges ({:.4}% dense)",
        graph.rows,
        graph.nnz(),
        graph.density() * 100.0
    );
    let vee = Vee::new(Topology::host(), SchedConfig::default());

    let configs = [
        ("DAPHNE default", SchedConfig::default()), // STATIC, central
        (
            "MFSC central",
            SchedConfig::default().with_scheme(Scheme::Mfsc),
        ),
        (
            "TFSS + work-stealing (RNDPRI)",
            SchedConfig::default()
                .with_scheme(Scheme::Tfss)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::RndPri),
        ),
    ];

    for (label, config) in configs {
        // with_config shares the resident pool; only the job config changes
        let result = cc::run_with(&vee.with_config(config), &graph, 100);
        println!(
            "{label:<32} {} components in {} iterations, {:.4}s scheduled, \
             {} steals",
            result.components,
            result.iterations,
            result.total_time(),
            result
                .reports
                .iter()
                .chain(&result.diff_reports)
                .map(|r| r.total_steals())
                .sum::<usize>(),
        );
    }
    println!(
        "all runs shared one pool: {} jobs on {} workers",
        vee.executor().unwrap().jobs_completed(),
        vee.executor().unwrap().n_workers()
    );
}
