//! Legacy spawn-per-run entry points, kept as thin deprecated shims
//! over a one-shot [`Executor`](super::executor::Executor).
//!
//! The real-thread execution path lives in [`super::executor`]: workers
//! are spawned once per topology and parked between jobs. `ThreadPool`
//! and [`run_once`] reproduce the seed's spawn-per-stage behaviour
//! (construct pool → run one job → join) for callers that want a
//! one-shot execution — they exist so the `sim` crate's shared
//! components and older examples keep working, and as the baseline leg
//! of the spawn-vs-persistent microbenchmark (`benches/micro.rs`).

use std::sync::Arc;

use super::executor::{Executor, JobSpec};
use super::metrics::SchedReport;
use super::task::TaskRange;
use crate::config::SchedConfig;
use crate::topology::Topology;

/// One-shot worker pool: spawns `topo.n_cores()` threads per [`run`]
/// call and joins them before returning.
///
/// [`run`]: ThreadPool::run
#[deprecated(
    note = "use sched::executor::Executor — it keeps workers resident \
            across jobs instead of respawning per run"
)]
pub struct ThreadPool {
    topo: Topology,
    config: SchedConfig,
}

#[allow(deprecated)]
impl ThreadPool {
    pub fn new(topo: Topology, config: SchedConfig) -> Self {
        ThreadPool { topo, config }
    }

    /// Schedule `total` work items over a freshly spawned pool;
    /// `body(worker, range)` executes one task. Returns the scheduling
    /// report.
    ///
    /// `body` must be safe to call concurrently for disjoint ranges —
    /// the partitioning invariant (tested in [`super::queue`]) guarantees
    /// every item index is handed out exactly once.
    pub fn run<F>(&self, total: usize, body: F) -> SchedReport
    where
        F: Fn(usize, TaskRange) + Send + Sync,
    {
        let exec = Executor::new(
            Arc::new(self.topo.clone()),
            Arc::new(self.config.clone()),
        );
        exec.run(JobSpec::new(total), body)
        // `exec` drops here: shutdown + join, i.e. the seed's
        // thread::scope semantics.
    }
}

/// Convenience: run one configuration end-to-end on a one-shot pool.
#[deprecated(
    note = "construct a persistent sched::executor::Executor and call \
            `run`/`submit` instead of respawning threads per call"
)]
pub fn run_once<F>(
    topo: &Topology,
    config: &SchedConfig,
    total: usize,
    body: F,
) -> SchedReport
where
    F: Fn(usize, TaskRange) + Send + Sync,
{
    #[allow(deprecated)]
    let pool = ThreadPool::new(topo.clone(), config.clone());
    pool.run(total, body)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]

    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sched::queue::QueueLayout;
    use crate::sched::victim::VictimStrategy;
    use crate::util::prop;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn host4() -> Topology {
        Topology::symmetric("test4", 2, 2, 1.5, 1.0)
    }

    fn count_items(topo: &Topology, config: &SchedConfig, total: usize) -> SchedReport {
        let hits: Vec<AtomicUsize> =
            (0..total).map(|_| AtomicUsize::new(0)).collect();
        let report = run_once(topo, config, total, |_w, range| {
            for i in range.iter() {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} executed != once");
        }
        report
    }

    #[test]
    fn centralized_executes_every_item_once() {
        let cfg = SchedConfig::default().with_scheme(Scheme::Gss);
        let r = count_items(&host4(), &cfg, 10_000);
        assert_eq!(r.total_items(), 10_000);
        assert_eq!(r.total_steals(), 0);
    }

    #[test]
    fn percore_with_stealing_executes_every_item_once() {
        for victim in VictimStrategy::ALL {
            let cfg = SchedConfig::default()
                .with_scheme(Scheme::Fac2)
                .with_layout(QueueLayout::PerCore)
                .with_victim(victim);
            let r = count_items(&host4(), &cfg, 5_000);
            assert_eq!(r.total_items(), 5_000, "{victim:?}");
        }
    }

    #[test]
    fn pergroup_executes_every_item_once() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Tss)
            .with_layout(QueueLayout::PerGroup)
            .with_victim(VictimStrategy::SeqPri);
        let r = count_items(&host4(), &cfg, 7_777);
        assert_eq!(r.total_items(), 7_777);
    }

    #[test]
    fn atomic_central_executes_every_item_once() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Mfsc)
            .with_layout(QueueLayout::Centralized { atomic: true });
        let r = count_items(&host4(), &cfg, 12_345);
        assert_eq!(r.total_items(), 12_345);
    }

    #[test]
    fn skewed_work_induces_steals_under_percore() {
        // All the cost in the first block: workers owning later blocks
        // finish instantly and must steal.
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Fac2)
            .with_layout(QueueLayout::PerCore)
            .with_victim(VictimStrategy::Seq);
        let r = run_once(&host4(), &cfg, 4_000, |_w, range| {
            for i in range.iter() {
                if i < 1000 {
                    std::hint::black_box((0..2_000).sum::<u64>());
                }
            }
        });
        assert!(
            r.total_steals() > 0,
            "skew must trigger stealing: {:?}",
            r.row()
        );
    }

    #[test]
    fn report_names_match_config() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Pss)
            .with_layout(QueueLayout::PerCore)
            .with_victim(VictimStrategy::RndPri);
        let r = count_items(&host4(), &cfg, 100);
        assert_eq!(r.scheme, "PSS");
        assert_eq!(r.layout, "PERCORE");
        assert_eq!(r.victim, "RNDPRI");
    }

    #[test]
    fn prop_all_configs_execute_exactly_once() {
        prop::check("thread pool executes every item once", 25, |rng| {
            let scheme = *rng.choose(&Scheme::ALL);
            let layout = *rng.choose(&[
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ]);
            let victim = *rng.choose(&VictimStrategy::ALL);
            let total = rng.range(1, 5_000) as usize;
            let cfg = SchedConfig {
                scheme,
                layout,
                victim,
                seed: rng.next_u64(),
                stages: None,
                pls_swr: 0.5,
            };
            let hits: Vec<AtomicUsize> =
                (0..total).map(|_| AtomicUsize::new(0)).collect();
            run_once(&host4(), &cfg, total, |_w, range| {
                for i in range.iter() {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                prop::ensure(
                    h.load(Ordering::Relaxed) == 1,
                    format!(
                        "{scheme:?}/{layout:?}/{victim:?}: item {i} ran {}x",
                        h.load(Ordering::Relaxed)
                    ),
                )?;
            }
            Ok(())
        });
    }
}
