//! Trace-agreement acceptance (the PR 8 tentpole pin): the real
//! executor and the DES replay the SAME seeded burst of requests
//! through the same `Bounded` admission gate, both with the event
//! trace armed, and must agree on
//!
//! 1. the per-request admission decision sequence (and its Admit/Shed
//!    event stream),
//! 2. per admitted request, the per-node event ordering — every node
//!    records exactly one Enqueue ≤ Dispatch ≤ NodeComplete, and a
//!    parent's NodeComplete never trails its child's Dispatch,
//! 3. shed requests record no node events at all on either engine.
//!
//! Node names are unique per request, so per-node streams are matched
//! across engines by FNV-1a name hash (job ids differ by engine). The
//! DES emits no Park/Unpark/FailedSteal (those are real-pool artifacts)
//! — the comparison filters to the shared kinds.
//!
//! This suite owns its process, so arming the global trace gate is safe
//! (the lib unit tests deliberately never touch it).

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::collections::BTreeMap;
use std::sync::Arc;

use daphne_sched::config::{SchedConfig, TraceMode};
use daphne_sched::obs::export;
use daphne_sched::obs::trace::{self, TraceEvent};
use daphne_sched::obs::TraceKind;
use daphne_sched::sched::{
    AdmissionPolicy, Admitted, Executor, GraphSpec, NodeSpec, SubmitOpts,
    TenancyPolicy,
};
use daphne_sched::sim::{
    self, GraphShape, NodeModel, SimAdmission, TenantSpec,
};
use daphne_sched::topology::Topology;
use daphne_sched::util::json;

const REQUESTS: usize = 4;
const BOUND: usize = 2;
const ROWS: usize = 8;
const TAG: &str = "rq";

fn topo2() -> Topology {
    Topology::symmetric("t2", 1, 2, 1.0, 1.0)
}

/// The three chained stages of request `i`, with per-request-unique
/// node names so event streams match across engines by name hash.
fn node_names(i: usize) -> [String; 3] {
    [
        format!("req{i}.colstats"),
        format!("req{i}.stats"),
        format!("req{i}.standardize"),
    ]
}

fn des_tenant(i: usize) -> TenantSpec {
    let [a, b, c] = node_names(i);
    let per_item = 1e-3;
    let shape = GraphShape::new(&format!("req{i}"))
        .node(NodeModel::uniform(&a, ROWS, per_item))
        .node(NodeModel::uniform(&b, 1, per_item).after(&a))
        .node(NodeModel::uniform(&c, ROWS, per_item).after(&b));
    // every request arrives at t = 0: a burst, so `Bounded { 2 }`
    // accepts exactly the first two in spec order
    TenantSpec::new(&format!("req{i}"), shape, 0.0).tag(TAG)
}

/// Enough real work per item that the first admitted request cannot
/// drain before the last submission of the burst lands (the decisions
/// then have no timing dependence, exactly as in the DES).
fn spin_item() {
    let mut x = 0u64;
    for i in 0..200_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(x);
}

fn real_request(i: usize) -> GraphSpec {
    let [a, b, c] = node_names(i);
    GraphSpec::new(&format!("req{i}"))
        .node(NodeSpec::new(&a, ROWS), |_w, _r| spin_item())
        .node(NodeSpec::new(&b, 1).after(&a), |_w, _r| spin_item())
        .node(NodeSpec::new(&c, ROWS).after(&b), |_w, _r| spin_item())
}

/// Admit/Shed stream for one engine: `(kind, graph-name hash)` in
/// timeline order, restricted to the shared tag.
fn admission_seq(events: &[TraceEvent], tag: u64) -> Vec<(TraceKind, u64)> {
    events
        .iter()
        .filter(|e| {
            e.tag_hash == tag
                && matches!(e.kind, TraceKind::Admit | TraceKind::Shed)
        })
        .map(|e| (e.kind, e.name_hash))
        .collect()
}

/// First timestamp of `kind` for the node hashed `name`, plus the
/// event count of that kind (node events only, shared-kind filter).
fn node_kind(
    events: &[TraceEvent],
    name: u64,
    kind: TraceKind,
) -> (Option<u64>, usize) {
    let mut first = None;
    let mut count = 0;
    for e in events {
        if e.name_hash == name && e.kind == kind {
            first.get_or_insert(e.ts_ns);
            count += 1;
        }
    }
    (first, count)
}

/// Assert one engine's stream obeys the per-request pin: admitted
/// chains record each of Enqueue/Dispatch/NodeComplete exactly once
/// per node in order, parents complete before children dispatch, and
/// shed chains record nothing.
fn check_engine(events: &[TraceEvent], decisions: &[bool], engine: &str) {
    for (i, &admitted) in decisions.iter().enumerate() {
        let hashes: Vec<u64> =
            node_names(i).iter().map(|n| trace::fnv1a(n)).collect();
        if !admitted {
            for (&h, name) in hashes.iter().zip(node_names(i).iter()) {
                for kind in [
                    TraceKind::Enqueue,
                    TraceKind::Dispatch,
                    TraceKind::NodeComplete,
                ] {
                    let (_, count) = node_kind(events, h, kind);
                    assert_eq!(
                        count, 0,
                        "{engine}: shed req{i} node {name} must record \
                         no {kind:?} events"
                    );
                }
            }
            continue;
        }
        let mut prev_complete = 0u64;
        for (&h, name) in hashes.iter().zip(node_names(i).iter()) {
            let (enq, n_enq) = node_kind(events, h, TraceKind::Enqueue);
            let (dis, n_dis) = node_kind(events, h, TraceKind::Dispatch);
            let (done, n_done) =
                node_kind(events, h, TraceKind::NodeComplete);
            assert_eq!(
                (n_enq, n_dis, n_done),
                (1, 1, 1),
                "{engine}: node {name} must record each of \
                 Enqueue/Dispatch/NodeComplete exactly once"
            );
            let (enq, dis, done) =
                (enq.unwrap(), dis.unwrap(), done.unwrap());
            assert!(
                enq <= dis && dis <= done,
                "{engine}: node {name} must order \
                 Enqueue({enq}) <= Dispatch({dis}) <= NodeComplete({done})"
            );
            assert!(
                prev_complete <= dis,
                "{engine}: node {name} dispatched at {dis} before its \
                 parent completed at {prev_complete}"
            );
            prev_complete = done;
        }
    }
}

/// One test function: the trace buffer is process-global, so the DES
/// and real halves must run sequentially in a single test.
#[test]
fn real_and_des_traces_agree_on_a_shared_admitted_burst() {
    trace::enable(TraceMode::On, 2, 4096);
    let _ = trace::drain();
    let tag = trace::fnv1a(TAG);
    let admission = AdmissionPolicy::Bounded { max_backlog: BOUND };

    // --- DES half: one burst replay under admission, virtual time ---
    let tenants: Vec<TenantSpec> = (0..REQUESTS).map(des_tenant).collect();
    // isolated baselines feed only the slowdown metric, unused here
    let isolated = vec![0.0; REQUESTS];
    let (_outcome, des_decisions) = sim::replay_tenants_admitted(
        &tenants,
        &topo2(),
        &SchedConfig::fine_grained(),
        &sim::CostModel::recorded(),
        TenancyPolicy::Fifo,
        &isolated,
        Some(&SimAdmission {
            policy: admission,
            tag: TAG.to_string(),
            est_cost: 1e-3,
        }),
    )
    .unwrap();
    let des_events = trace::drain();

    // --- real half: the same burst through one session ---
    let exec = Executor::new_with_policy(
        Arc::new(topo2()),
        Arc::new(SchedConfig::fine_grained()),
        TenancyPolicy::Fifo,
    );
    let session = exec.session();
    let mut real_decisions = Vec::new();
    let mut handles = Vec::new();
    for i in 0..REQUESTS {
        let opts = SubmitOpts::new()
            .tag(TAG)
            .admission(admission)
            .est_cost(1e-3);
        match session.try_submit_graph(real_request(i), opts).unwrap() {
            Admitted::Accepted(h) => {
                real_decisions.push(true);
                handles.push(h);
            }
            Admitted::Rejected { .. } => real_decisions.push(false),
        }
    }
    for h in handles {
        h.wait();
    }
    let real_events = trace::drain();

    // 1. admission parity: both engines accept exactly the first BOUND
    // arrivals, and their Admit/Shed event streams agree
    let expected: Vec<bool> = (0..REQUESTS).map(|i| i < BOUND).collect();
    assert_eq!(des_decisions, expected, "DES admits exactly the bound");
    assert_eq!(
        real_decisions, des_decisions,
        "real loop must reproduce the DES admission decisions"
    );
    let expected_adm: Vec<(TraceKind, u64)> = (0..REQUESTS)
        .map(|i| {
            let kind = if i < BOUND {
                TraceKind::Admit
            } else {
                TraceKind::Shed
            };
            (kind, trace::fnv1a(&format!("req{i}")))
        })
        .collect();
    assert_eq!(admission_seq(&des_events, tag), expected_adm);
    assert_eq!(admission_seq(&real_events, tag), expected_adm);

    // 2 + 3. per-node event-ordering pin, each engine against the
    // shared decision vector
    check_engine(&des_events, &des_decisions, "des");
    check_engine(&real_events, &real_decisions, "real");

    // per-node Enqueue/Dispatch/NodeComplete subsequences are equal
    // across engines. The multiset is compared sorted: same-timestamp
    // events land in lane order in the merged stream (a DES burst
    // stamps Enqueue and first Dispatch both at t = 0), so raw drain
    // order is not comparable across engines — the true ordering pin
    // is the per-kind timestamp chain checked above.
    let collect = |events: &[TraceEvent]| -> BTreeMap<u64, Vec<TraceKind>> {
        let mut m: BTreeMap<u64, Vec<TraceKind>> = BTreeMap::new();
        for i in 0..REQUESTS {
            for name in node_names(i).iter() {
                m.entry(trace::fnv1a(name)).or_default();
            }
        }
        for e in events {
            if matches!(
                e.kind,
                TraceKind::Enqueue
                    | TraceKind::Dispatch
                    | TraceKind::NodeComplete
            ) {
                if let Some(seq) = m.get_mut(&e.name_hash) {
                    seq.push(e.kind);
                }
            }
        }
        for seq in m.values_mut() {
            seq.sort();
        }
        m
    };
    assert_eq!(
        collect(&des_events),
        collect(&real_events),
        "per-node shared-kind subsequences must match across engines"
    );

    // the differential differ agrees with the hand-rolled comparison:
    // the two engines replayed the same burst, so no node's shared-kind
    // sequence differs and no node is one-sided (acceptance criterion:
    // zero ordering skew on the shared burst)
    let diff = daphne_sched::obs::diff_traces(&des_events, &real_events);
    assert_eq!(
        diff.ordering_skew, 0,
        "real-vs-DES diff must report zero ordering skew on the shared \
         burst: {}",
        diff.render(6)
    );
    // both sides saw the same admitted node set
    assert!(diff
        .nodes
        .iter()
        .all(|n| n.modelled_ns.is_some() && n.measured_ns.is_some()));

    // the exporter renders the real stream to well-formed Chrome-trace
    // JSON (the CI smoke validates the CLI-written file the same way)
    let doc = export::chrome_trace_json(&real_events);
    let parsed = json::parse(&json::to_string(&doc)).unwrap();
    let traced = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!traced.is_empty(), "chrome trace must carry events");
    assert!(traced.iter().all(|e| e.get("ph").is_some()
        && e.get("pid").is_some()));
}
