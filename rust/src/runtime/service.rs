//! Device service: a dedicated thread owning the PJRT runtime.
//!
//! The `xla` crate's client/executable handles are thread-confined
//! (`Rc` + raw pointers), and DAPHNE's worker manager likewise fronts
//! accelerators with dedicated threads that "perform data transfers and
//! launch kernels on target devices" (§3). [`DeviceService`] is that
//! thread; scheduler workers talk to it through the cloneable
//! [`DeviceClient`].

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::{Manifest, Runtime};

struct Request {
    stage: String,
    inputs: Vec<Vec<f32>>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>, String>>,
}

/// Handle to the device thread; dropping it shuts the service down.
pub struct DeviceService {
    tx: Option<mpsc::Sender<Request>>,
    handle: Option<JoinHandle<()>>,
    pub platform: String,
    /// Artifact metadata (shapes) for callers that tile data.
    pub manifest: Manifest,
}

/// Cloneable, `Send` client used from scheduler workers.
#[derive(Clone)]
pub struct DeviceClient {
    tx: mpsc::Sender<Request>,
}

impl DeviceService {
    /// Start the service; loads and compiles artifacts inside the
    /// service thread (the runtime is created and dies there).
    pub fn start(dir: PathBuf) -> Result<(DeviceService, DeviceClient)> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (meta_tx, meta_rx) = mpsc::channel::<Result<String, String>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = meta_tx.send(Ok(rt.platform.clone()));
                        rt
                    }
                    Err(e) => {
                        let _ = meta_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let result = runtime.stage(&req.stage).and_then(|stage| {
                        let refs: Vec<&[f32]> =
                            req.inputs.iter().map(|v| v.as_slice()).collect();
                        stage.run_f32(&refs)
                    });
                    let _ = req.reply.send(result.map_err(|e| format!("{e:#}")));
                }
            })?;
        let platform = meta_rx
            .recv()
            .map_err(|_| anyhow!("device thread died during startup"))?
            .map_err(|e| anyhow!(e))?;
        Ok((
            DeviceService {
                tx: Some(tx.clone()),
                handle: Some(handle),
                platform,
                manifest,
            },
            DeviceClient { tx },
        ))
    }

    /// Start against the default artifact dir.
    pub fn start_default() -> Result<(DeviceService, DeviceClient)> {
        Self::start(Runtime::default_dir())
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl DeviceClient {
    /// Execute a stage on the device thread; blocks for the reply.
    pub fn run_f32(
        &self,
        stage: &str,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request {
                stage: stage.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("device service is down"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("device service dropped the request"))?
            .map_err(|e| anyhow!(e))
    }
}
