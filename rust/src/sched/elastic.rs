//! Elastic device pools: runtime-resizable worker↔pool assignment and
//! the SLO-driven scaling controller.
//!
//! [`DevicePools`](super::placement::DevicePools) partitions workers by
//! device class once, at executor construction, and stays immutable —
//! the placement oracle, task sources, and per-pool sub-topologies all
//! key off it. Elasticity is layered *on top* as an overlay:
//! [`ElasticPools`] tracks, per worker, which pool it currently serves
//! (`assignment`) and whether it participates at all (`active`), both
//! as atomics so the dispatch path stays lock-free. The worker's
//! *home* pool (its `DevicePools` pool) never changes; a lease moves
//! only the assignment.
//!
//! The eligibility rule the executor enforces with this overlay:
//!
//! - a worker picks jobs from its **assigned** pool only;
//! - on a **foreign** pool (assignment ≠ home) it serves **moldable**
//!   jobs only ([`SubmitOpts::moldable`](super::SubmitOpts::moldable)).
//!
//! Together these preserve the placement invariant under resizing: a
//! pinned (non-moldable) job only ever runs on workers whose *home* is
//! its pool, because a borrowed worker is never eligible for it — and
//! the moment a non-moldable job is enqueued on a lending pool, the
//! executor snaps every lease back ([`ElasticPools::reclaim_if_lent`]).
//!
//! Mutations (lend / reclaim / resize) serialize on the `lease` lock at
//! rank [`ranks::ELASTIC_LEASE`] — below the run queue, so a caller may
//! still take the queue lock to wake parked workers while deciding.
//! In-flight tasks are never dropped: a re-homed worker finishes its
//! current chunk, notices the assignment change at the next pull, and
//! yields the stint; the remaining task ranges stay in the job's source
//! for the pool's other workers.
//!
//! This module is pure scheduler state: no `obs` dependency (repolint's
//! `layering-elastic` rule). Trace events ([`TraceKind::Resize`]) and
//! the pool-width gauges are recorded by the call sites in `session`
//! and the executor, keeping the controller replayable in the DES
//! mirror (`sim::elastic`) byte-for-byte.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use super::placement::DevicePools;
use super::ranks;
use crate::util::ordered::OrderedMutex;

/// Lease table: which workers are currently lent away from home.
struct LeaseState {
    /// Global worker ids currently assigned to a foreign pool.
    lent: Vec<usize>,
}

/// Runtime-resizable overlay over an immutable [`DevicePools`]
/// partition. Reads (`assignment_of` / `is_active` / `epoch`) are
/// single relaxed atomic loads — safe on the dispatch path; mutations
/// serialize on the ranked `lease` lock.
pub struct ElasticPools {
    /// Worker → home pool (the immutable `DevicePools` partition).
    home: Vec<usize>,
    /// Worker → pool it currently serves.
    assignment: Vec<AtomicUsize>,
    /// Worker → participating? `false` = parked out by `set_width`.
    active: Vec<AtomicBool>,
    /// Bumped on every assignment/active mutation (resize-cycle count).
    epoch: AtomicU64,
    /// Serializes lend / reclaim / resize (rank `elastic.lease`).
    lease: OrderedMutex<LeaseState>,
    n_pools: usize,
}

impl ElasticPools {
    pub fn new(pools: &DevicePools) -> Self {
        let n = pools.n_workers();
        let home: Vec<usize> = (0..n).map(|w| pools.pool_of(w)).collect();
        ElasticPools {
            assignment: home.iter().map(|&p| AtomicUsize::new(p)).collect(),
            active: (0..n).map(|_| AtomicBool::new(true)).collect(),
            epoch: AtomicU64::new(0),
            lease: OrderedMutex::new(ranks::ELASTIC_LEASE, LeaseState { lent: Vec::new() }),
            n_pools: pools.n_pools(),
            home,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.home.len()
    }

    pub fn n_pools(&self) -> usize {
        self.n_pools
    }

    /// The worker's immutable home pool.
    #[inline]
    pub fn home_of(&self, w: usize) -> usize {
        self.home[w]
    }

    /// The pool the worker currently serves (relaxed load).
    #[inline]
    pub fn assignment_of(&self, w: usize) -> usize {
        self.assignment[w].load(Ordering::Relaxed)
    }

    /// Whether the worker participates in dispatch at all.
    #[inline]
    pub fn is_active(&self, w: usize) -> bool {
        self.active[w].load(Ordering::Relaxed)
    }

    /// Resize-cycle counter: bumped on every mutation.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Current width of `pool`: active workers assigned to it
    /// (home members minus parked/lent, plus borrowed).
    pub fn width(&self, pool: usize) -> usize {
        (0..self.home.len())
            .filter(|&w| self.assignment_of(w) == pool && self.is_active(w))
            .count()
    }

    /// Widths of every pool, indexed by pool id.
    pub fn widths(&self) -> Vec<usize> {
        (0..self.n_pools).map(|p| self.width(p)).collect()
    }

    /// How many of `pool`'s home workers are currently lent away.
    /// Lock-free (derived from the assignment atomics), so the enqueue
    /// path can use it as a cheap snap-back trigger test.
    pub fn lent_out(&self, pool: usize) -> usize {
        (0..self.home.len())
            .filter(|&w| self.home[w] == pool && self.assignment_of(w) != pool)
            .count()
    }

    /// Lend up to `n` idle-eligible workers from pool `from` to pool
    /// `to`: active workers resident at home (`assignment == home ==
    /// from`). Returns how many moved. The caller is responsible for
    /// waking parked workers afterwards.
    pub fn lend(&self, from: usize, to: usize, n: usize) -> usize {
        if from == to || from >= self.n_pools || to >= self.n_pools || n == 0 {
            return 0;
        }
        let mut lease = self.lease.lock().unwrap();
        let mut moved = 0;
        for w in 0..self.home.len() {
            if moved == n {
                break;
            }
            if self.home[w] == from && self.assignment_of(w) == from && self.is_active(w) {
                self.assignment[w].store(to, Ordering::Relaxed);
                lease.lent.push(w);
                moved += 1;
            }
        }
        if moved > 0 {
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
        drop(lease);
        moved
    }

    /// Return every worker whose home is `pool` to it (snap-back).
    /// Returns how many came home.
    pub fn reclaim(&self, pool: usize) -> usize {
        let mut lease = self.lease.lock().unwrap();
        let mut returned = 0;
        lease.lent.retain(|&w| {
            if self.home[w] == pool {
                self.assignment[w].store(pool, Ordering::Relaxed);
                returned += 1;
                false
            } else {
                true
            }
        });
        if returned > 0 {
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
        drop(lease);
        returned
    }

    /// Snap-back fast path for the enqueue hook: a lock-free check
    /// first, the lease lock only when a lease actually exists.
    pub fn reclaim_if_lent(&self, pool: usize) -> usize {
        if self.lent_out(pool) == 0 {
            return 0;
        }
        self.reclaim(pool)
    }

    /// Park or unpark home-resident workers of `pool` so its resident
    /// width becomes `width` (clamped to `1..=residents`; a pool never
    /// drops to zero by resizing — only lends can empty it, and those
    /// snap back on demand). Workers lent away are untouched. Returns
    /// the resulting resident width.
    pub fn set_width(&self, pool: usize, width: usize) -> usize {
        if pool >= self.n_pools {
            return 0;
        }
        let lease = self.lease.lock().unwrap();
        let residents: Vec<usize> = (0..self.home.len())
            .filter(|&w| self.home[w] == pool && self.assignment_of(w) == pool)
            .collect();
        let target = width.clamp(1, residents.len().max(1));
        let mut changed = false;
        for (i, &w) in residents.iter().enumerate() {
            let want = i < target;
            if self.active[w].load(Ordering::Relaxed) != want {
                self.active[w].store(want, Ordering::Relaxed);
                changed = true;
            }
        }
        if changed {
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
        drop(lease);
        target.min(residents.len())
    }
}

/// Tuning knobs for the serve-soak scaling controller. All decisions
/// derive from these plus the per-interval [`Signals`], so the DES
/// mirror replays the exact controller the real soak runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerCfg {
    /// The latency objective in seconds (serve's `slo_ms` / 1000).
    pub slo: f64,
    /// Width floor for the serving pool — `Reclaim` is only issued
    /// while the pool is wider than this (normally its base width).
    pub min_workers: usize,
    /// Width ceiling for the serving pool — `Lend` stops here.
    pub max_workers: usize,
    /// Consecutive breached-and-climbing intervals before lending.
    pub patience: usize,
    /// Workers moved per `Lend` decision.
    pub step: usize,
    /// Failed-steal ratio above which a non-breached pool is judged
    /// too wide and gives borrowed workers back.
    pub fail_steal_hi: f64,
}

impl Default for ControllerCfg {
    fn default() -> Self {
        ControllerCfg {
            slo: 0.010,
            min_workers: 1,
            max_workers: usize::MAX,
            patience: 2,
            step: 2,
            fail_steal_hi: 0.5,
        }
    }
}

/// One control interval's observations, assembled by the caller from
/// the latency reservoir and the `obs::live` counters (real soak) or
/// their virtual-time analogues (DES).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Signals {
    /// Rolling p99 of served request latency, seconds.
    pub p99: f64,
    /// Backlog high-water observed this interval.
    pub backlog: u64,
    /// failed steals / steal attempts this interval (0 if none).
    pub failed_steal_ratio: f64,
    /// The donor pool has live non-moldable work of its own.
    pub donor_busy: bool,
    /// Current width of the serving pool.
    pub width: usize,
}

/// A resize decision for the serving pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Borrow `n` workers from the donor pool.
    Lend(usize),
    /// Return every borrowed worker to its home pool.
    Reclaim,
}

impl ScaleDecision {
    pub fn describe(&self) -> String {
        match self {
            ScaleDecision::Hold => "hold".to_string(),
            ScaleDecision::Lend(n) => format!("lend:{n}"),
            ScaleDecision::Reclaim => "reclaim".to_string(),
        }
    }
}

/// The SLO-driven scaling controller: pure, deterministic state machine
/// over [`Signals`] — identical in the real soak and the DES mirror.
///
/// Policy, in priority order:
/// 1. the donor needs its cores back (`donor_busy` while lent) ⇒
///    [`ScaleDecision::Reclaim`] — placement snaps back first;
/// 2. p99 over SLO *and* backlog high-water climbing for `patience`
///    consecutive intervals ⇒ capacity gap ⇒ [`ScaleDecision::Lend`];
/// 3. lent, SLO met, and a sustained failed-steal ratio ⇒ the pool is
///    too wide for the offered load ⇒ [`ScaleDecision::Reclaim`];
/// 4. otherwise hold. Admission (`bounded`/`shed`) stays the guard
///    while capacity catches up — the controller never sheds.
#[derive(Debug, Clone)]
pub struct ScalingController {
    cfg: ControllerCfg,
    streak: usize,
    prev_backlog: u64,
}

impl ScalingController {
    pub fn new(cfg: ControllerCfg) -> Self {
        ScalingController {
            cfg,
            streak: 0,
            prev_backlog: 0,
        }
    }

    pub fn cfg(&self) -> &ControllerCfg {
        &self.cfg
    }

    /// Evaluate one control interval.
    pub fn decide(&mut self, s: &Signals) -> ScaleDecision {
        let over_floor = s.width > self.cfg.min_workers;
        let breach = s.p99 > self.cfg.slo;
        // "Climbing" includes holding a saturated high-water: under a
        // bounded-admission burst the high-water pins at max_backlog.
        let climbing = s.backlog > 0 && s.backlog >= self.prev_backlog;
        self.prev_backlog = s.backlog;
        if over_floor && s.donor_busy {
            self.streak = 0;
            return ScaleDecision::Reclaim;
        }
        if breach && climbing {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        if self.streak >= self.cfg.patience && s.width < self.cfg.max_workers {
            self.streak = 0;
            let room = self.cfg.max_workers - s.width;
            return ScaleDecision::Lend(self.cfg.step.clamp(1, room));
        }
        if over_floor && !breach && s.failed_steal_ratio > self.cfg.fail_steal_hi {
            return ScaleDecision::Reclaim;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DeviceClass, Topology};
    use std::sync::Arc;

    fn hetero_pools() -> (Arc<Topology>, DevicePools) {
        let topo = Arc::new(Topology::heterogeneous(
            "h",
            1,
            2,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        ));
        let pools = DevicePools::new(&topo);
        (topo, pools)
    }

    #[test]
    fn lend_moves_assignment_and_reclaim_restores_it() {
        let (_t, pools) = hetero_pools();
        let el = ElasticPools::new(&pools);
        assert_eq!(el.widths(), vec![2, 2]);
        assert_eq!(el.epoch(), 0);

        let moved = el.lend(1, 0, 2);
        assert_eq!(moved, 2);
        assert_eq!(el.widths(), vec![4, 0]);
        assert_eq!(el.lent_out(1), 2);
        assert_eq!(el.home_of(2), 1);
        assert_eq!(el.assignment_of(2), 0);
        assert_eq!(el.epoch(), 1);

        // Idempotent: nothing left to lend.
        assert_eq!(el.lend(1, 0, 2), 0);
        assert_eq!(el.epoch(), 1);

        assert_eq!(el.reclaim(1), 2);
        assert_eq!(el.widths(), vec![2, 2]);
        assert_eq!(el.lent_out(1), 0);
        assert_eq!(el.epoch(), 2);
        assert_eq!(el.reclaim_if_lent(1), 0);
    }

    #[test]
    fn lend_caps_at_available_and_rejects_self_lease() {
        let (_t, pools) = hetero_pools();
        let el = ElasticPools::new(&pools);
        assert_eq!(el.lend(1, 1, 2), 0);
        assert_eq!(el.lend(7, 0, 2), 0);
        assert_eq!(el.lend(1, 0, 99), 2);
        assert_eq!(el.width(0), 4);
    }

    #[test]
    fn set_width_parks_and_unparks_residents_with_floor_of_one() {
        let (_t, pools) = hetero_pools();
        let el = ElasticPools::new(&pools);
        assert_eq!(el.set_width(0, 1), 1);
        assert_eq!(el.width(0), 1);
        assert!(el.is_active(0) && !el.is_active(1));
        // Clamps: can't go to zero, can't exceed residents.
        assert_eq!(el.set_width(0, 0), 1);
        assert_eq!(el.set_width(0, 99), 2);
        assert_eq!(el.width(0), 2);
        // Parked donors are not lendable.
        el.set_width(1, 1);
        assert_eq!(el.lend(1, 0, 2), 1);
    }

    #[test]
    fn controller_lends_after_sustained_breach_with_climbing_backlog() {
        let mut ctl = ScalingController::new(ControllerCfg {
            slo: 0.010,
            min_workers: 4,
            max_workers: 6,
            patience: 2,
            step: 2,
            fail_steal_hi: 0.5,
        });
        let mut s = Signals {
            p99: 0.002,
            backlog: 0,
            failed_steal_ratio: 0.0,
            donor_busy: false,
            width: 4,
        };
        assert_eq!(ctl.decide(&s), ScaleDecision::Hold);
        s.p99 = 0.050;
        s.backlog = 3;
        assert_eq!(ctl.decide(&s), ScaleDecision::Hold); // streak 1
        s.backlog = 5;
        assert_eq!(ctl.decide(&s), ScaleDecision::Lend(2));
        // At the ceiling, no further lend even under breach.
        s.width = 6;
        assert_eq!(ctl.decide(&s), ScaleDecision::Hold);
        assert_eq!(ctl.decide(&s), ScaleDecision::Hold);
    }

    #[test]
    fn controller_reclaims_for_busy_donor_and_failed_steals() {
        let mut ctl = ScalingController::new(ControllerCfg {
            slo: 0.010,
            min_workers: 4,
            max_workers: 6,
            patience: 2,
            step: 2,
            fail_steal_hi: 0.5,
        });
        // Donor pressure wins even mid-breach.
        let s = Signals {
            p99: 0.050,
            backlog: 9,
            failed_steal_ratio: 0.0,
            donor_busy: true,
            width: 6,
        };
        assert_eq!(ctl.decide(&s), ScaleDecision::Reclaim);
        // SLO met + mostly-failing steals ⇒ the pool is too wide.
        let s = Signals {
            p99: 0.001,
            backlog: 0,
            failed_steal_ratio: 0.9,
            donor_busy: false,
            width: 6,
        };
        assert_eq!(ctl.decide(&s), ScaleDecision::Reclaim);
        // At the floor, never reclaim.
        let s = Signals { width: 4, ..s };
        assert_eq!(ctl.decide(&s), ScaleDecision::Hold);
    }

    #[test]
    fn decisions_describe_compactly() {
        assert_eq!(ScaleDecision::Hold.describe(), "hold");
        assert_eq!(ScaleDecision::Lend(2).describe(), "lend:2");
        assert_eq!(ScaleDecision::Reclaim.describe(), "reclaim");
    }
}
