//! DaphneSched — the paper's contribution (§3): a task-based scheduler
//! with two independent steps:
//!
//! 1. **Work partitioning** ([`partitioner`]): eleven self-scheduling
//!    techniques decide task granularity (variable-size tasks, Fig. 3b).
//! 2. **Work assignment** ([`queue`], [`victim`], [`executor`]):
//!    self-scheduling from a centralized queue, or work-stealing across
//!    per-core / per-NUMA-group queues with four victim-selection
//!    strategies.
//!
//! The novelty (contribution C.2) is that *stolen* work also follows the
//! chosen self-scheduling technique — a thief obtains the next chunk of
//! the victim's partition exactly as the owner would, so steal
//! granularity adapts instead of being a fixed constant.
//!
//! # Execution model
//!
//! Real-thread execution goes through the persistent [`Executor`]
//! (mirroring DAPHNE's resident worker pool, Fig. 2): threads are
//! spawned **once per topology** and parked between jobs. The
//! submission surface has two levels:
//!
//! 1. **Jobs** — one scheduled parallel region. [`Executor::submit`]
//!    returns a [`JobHandle`]; `handle.wait()` yields the
//!    [`SchedReport`]. Every job carries its own
//!    [`SchedConfig`](crate::config::SchedConfig), so one resident pool
//!    runs (or multiplexes, concurrently) STATIC and GSS jobs over the
//!    same workers; each job gets a job-scoped [`TaskSource`].
//! 2. **Task graphs** ([`graph`]) — a [`GraphSpec`] of named
//!    [`NodeSpec`]s with explicit `after(...)` dependency edges,
//!    submitted via [`Executor::submit_graph`] (owned bodies, returns a
//!    [`GraphHandle`]) or [`Executor::run_graph`] (borrowed bodies,
//!    blocks). The executor dispatches a node the moment its in-edges
//!    complete — a completion hook on each node's job enqueues the
//!    dependents that became ready, so independent branches overlap on
//!    the same workers with no coordinator thread. Cyclic specs are
//!    rejected as [`GraphError`]s up front; a node panic fails that
//!    node, cancels its transitive dependents, and leaves independent
//!    branches running.
//!
//! Pipelines ([`crate::vee::Pipeline`]) are sugar over level 2: a
//! linear `stage(...)` chain reproduces barrier-per-stage semantics
//! through dependency edges, `stage_after(...)` exposes branching, and
//! the `graph=barrier|dag` config knob switches a run between serial
//! stage order and dependency-aware dispatch for A/B comparison.
//!
//! # Multi-tenant sessions
//!
//! Many *competing* pipelines share one resident pool through the
//! [`Session`] API ([`session`]): [`Session::submit_graph`] attaches
//! tenancy options ([`SubmitOpts`]: priority, weight, tag) to a graph,
//! [`Session::submit_all`] fuses a batch of pipelines into one merged
//! scheduling horizon, and the executor's pluggable cross-job pick
//! policy ([`TenancyPolicy`]: FIFO, weighted-fair over tags, or strict
//! priority with aging) decides which tenant's tasks each free worker
//! serves. [`JobHandle::cancel`] / [`GraphHandle::cancel`] drop a
//! tenant's undispatched work to free the pool. The DES mirrors the
//! policies in virtual time ([`crate::sim::graph::replay_tenants`]) —
//! the oracle behind `figure tenancy` and [`autotune::tune_tenancy`].
//! [`Session::try_submit_graph`] adds admission control on top: an
//! [`AdmissionPolicy`] (`Open` | `Bounded` | `Shed`) checked against
//! the tag's live-job backlog ([`Executor::tag_backlog`]) decides
//! accept vs. reject before anything dispatches — the load-bearing
//! mechanism of the open-loop serving mode ([`crate::serve`]).
//!
//! # Heterogeneous device pools
//!
//! On a [`Topology::heterogeneous`](crate::topology::Topology) machine
//! the executor partitions its workers into one pool per
//! [`DeviceClass`](crate::topology::DeviceClass) at spawn
//! ([`placement`]): jobs and graph nodes carry a
//! [`placement::Placement`] (`Any` | `Class` | `Pool`) resolved against
//! those pools before dispatch. Task sources are pool-scoped, so a
//! placed node can neither execute on nor steal from a foreign pool,
//! and CPU and accelerator nodes overlap on disjoint workers; a
//! placement naming an absent class is a hard
//! [`GraphError::NoSuchPool`], never a deadlock. The DES replay and
//! graph autotuner model the same pools in virtual time, which makes
//! placement the fourth tuned dimension
//! (scheme × layout × victim × placement) of [`autotune::tune_graph`].
//!
//! Pool *widths* are no longer fixed for the life of the executor:
//! [`elastic`] overlays a runtime worker↔pool assignment on the
//! immutable partition, so [`Session::lend`]/[`Session::reclaim`]/
//! [`Session::resize_pool`] can move idle accelerator workers to a
//! CPU-bound moldable tenant ([`SubmitOpts::moldable`]) and snap them
//! back the moment a pinned node arrives, while an SLO-driven
//! [`ScalingController`] automates the same moves during `serve` soaks.
//!
//! The legacy spawn-per-run shims (`worker::run_once`, `ThreadPool`)
//! were removed after every caller migrated to the persistent
//! `Executor` (spawn-per-stage remains reproducible as
//! `executor=oneshot`); the DES ([`crate::sim`]) still drives the
//! *same* `TaskSource`/`VictimSelector` components in virtual time.
//!
//! # Prediction and tuning
//!
//! Both submission levels have virtual-time twins. Single jobs are
//! simulated by [`crate::sim::simulate`]; whole task graphs by
//! [`crate::sim::graph::replay`], which takes a cost-described
//! [`crate::sim::GraphShape`] (the DES sibling of [`GraphSpec`]) and
//! models dependency-aware dispatch on the paper's 20- and 56-core
//! machines. On top of them sits automatic selection ([`autotune`]):
//! [`autotune::tune`] sweeps (scheme × layout × victim) for one
//! workload, and [`autotune::tune_graph`] picks a *per-node*
//! configuration for a whole graph using replay as the oracle with a
//! greedy critical-path-first refinement — the §5 "automatic selection"
//! future work, lifted to pipelines.
//!
//! # Concurrency discipline
//!
//! Every scheduler mutex/condvar is an
//! [`OrderedMutex`](crate::util::ordered::OrderedMutex) /
//! [`OrderedCondvar`](crate::util::ordered::OrderedCondvar) tagged with
//! a [`LockRank`](crate::util::ordered::LockRank) from [`ranks`], the
//! declared total lock order. Debug builds panic on any down-rank
//! acquisition or a `wait` that holds a second lock; `tools/repolint`
//! enforces the same order (plus `SAFETY`/`SOUNDNESS` comment and
//! layering rules) syntactically in CI.

pub mod autotune;
pub mod elastic;
pub mod executor;
pub mod graph;
pub mod metrics;
pub mod partitioner;
pub mod placement;
pub mod queue;
pub mod ranks;
pub mod session;
pub mod stealing;
pub mod task;
pub mod victim;

pub use elastic::{
    ControllerCfg, ElasticPools, ScaleDecision, ScalingController, Signals,
};
pub use executor::{
    Executor, JobHandle, JobSpec, Scope, POLICY_REPICK_STRIDE,
};
pub use graph::{
    GraphError, GraphHandle, GraphReport, GraphSpec, NodeReport, NodeSpec,
    NodeStatus,
};
pub use metrics::{SchedReport, WorkerStats};
pub use partitioner::{ChunkCalc, Partitioner, Scheme};
pub use placement::{
    DevicePool, DevicePools, Placement, PlacementPolicy, PoolId,
};
pub use queue::{QueueLayout, TaskSource};
pub use session::{
    AdmissionPolicy, Admitted, Session, SubmitOpts, TenancyPolicy,
};
pub use task::TaskRange;
pub use victim::VictimStrategy;
