//! Std-only utility substrate: PRNGs, a minimal JSON parser (for the
//! artifact manifest), descriptive statistics, and a tiny property-testing
//! harness (the vendored crate set has no `rand`/`proptest`/`serde`).

pub mod disjoint;
pub mod json;
pub mod ordered;
pub mod prop;
pub mod rng;
pub mod stats;

pub use disjoint::DisjointMut;
pub use ordered::{LockRank, OrderedCondvar, OrderedMutex};
pub use rng::Rng;

/// Format a duration in engineer-friendly units.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500us");
        assert_eq!(fmt_duration(2.5e-8), "25.0ns");
    }
}
