//! Worker management (paper §3): the real-thread executor.
//!
//! Spawns one OS thread per topology place and drives the layout's
//! [`TaskSource`] with the configured victim selection. The DES
//! ([`crate::sim`]) drives the *same* `TaskSource`/`VictimSelector` in
//! virtual time; this executor is the ground-truth path used by tests,
//! examples and host-scale benchmarks.

use std::sync::Arc;
use std::time::Instant;

use super::metrics::{SchedReport, WorkerStats};
use super::partitioner::PartitionerOptions;
use super::queue::{self, TaskSource};
use super::stealing;
use super::task::TaskRange;
use super::victim::VictimSelector;
use crate::config::SchedConfig;
use crate::topology::Topology;

/// The real-thread worker pool.
pub struct ThreadPool {
    topo: Topology,
    config: SchedConfig,
}

impl ThreadPool {
    pub fn new(topo: Topology, config: SchedConfig) -> Self {
        ThreadPool { topo, config }
    }

    /// Schedule `total` work items over the pool; `body(worker, range)`
    /// executes one task. Returns the scheduling report.
    ///
    /// `body` must be safe to call concurrently for disjoint ranges —
    /// the partitioning invariant (tested in [`queue`]) guarantees
    /// every item index is handed out exactly once.
    pub fn run<F>(&self, total: usize, body: F) -> SchedReport
    where
        F: Fn(usize, TaskRange) + Send + Sync,
    {
        let opts = PartitionerOptions {
            stages: self.config.stages,
            pls_swr: self.config.pls_swr,
            seed: self.config.seed,
        };
        let source: Arc<Box<dyn TaskSource>> = Arc::new(queue::build_source(
            self.config.layout,
            self.config.scheme,
            total,
            &self.topo,
            &opts,
        ));
        let n = self.topo.n_cores();
        let body = &body;
        let start = Instant::now();

        let per_worker: Vec<WorkerStats> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for w in 0..n {
                let source = Arc::clone(&source);
                let topo = &self.topo;
                let config = &self.config;
                handles.push(scope.spawn(move || {
                    worker_loop(w, &**source, topo, config, body)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        SchedReport {
            scheme: self.config.scheme.name().to_string(),
            layout: self.config.layout.name().to_string(),
            victim: self.config.victim.name().to_string(),
            makespan: start.elapsed().as_secs_f64(),
            per_worker,
        }
    }
}

fn worker_loop<F>(
    w: usize,
    source: &dyn TaskSource,
    topo: &Topology,
    config: &SchedConfig,
    body: &F,
) -> WorkerStats
where
    F: Fn(usize, TaskRange) + Send + Sync,
{
    let mut stats = WorkerStats::default();
    let steals = config.layout.steals();
    let mut selector = steals.then(|| {
        let queue_socket: Vec<usize> = (0..source.n_queues())
            .map(|q| queue_socket_of(source, q, topo))
            .collect();
        VictimSelector::new(
            config.victim,
            source.queue_of(w),
            topo.socket_of(w.min(topo.n_cores() - 1)),
            queue_socket,
            config.seed ^ (w as u64).wrapping_mul(0x9E37_79B9),
        )
    });

    loop {
        let t0 = Instant::now();
        let pull = source.pull_local(w).or_else(|| {
            let selector = selector.as_mut()?;
            let out = stealing::steal_round(source, selector, w);
            stats.failed_steals +=
                out.attempts - usize::from(out.pull.is_some());
            out.pull
        });
        stats.queue_wait += t0.elapsed().as_secs_f64();

        let Some(pull) = pull else { break };
        if pull.stolen {
            stats.steals += 1;
            stats.stolen_items += pull.task.len();
        }

        let t1 = Instant::now();
        body(w, pull.task);
        stats.busy += t1.elapsed().as_secs_f64();
        stats.tasks += 1;
        stats.items += pull.task.len();
    }
    stats
}

/// NUMA domain a queue is homed on: for per-core layouts it is the
/// owner's socket, for per-group layouts the group index, for the
/// centralized layout socket 0.
fn queue_socket_of(source: &dyn TaskSource, q: usize, topo: &Topology) -> usize {
    if source.n_queues() == topo.n_cores() {
        topo.socket_of(q)
    } else if source.n_queues() == topo.sockets {
        q
    } else {
        0
    }
}

/// Convenience: run one configuration end-to-end (used by examples).
pub fn run_once<F>(
    topo: &Topology,
    config: &SchedConfig,
    total: usize,
    body: F,
) -> SchedReport
where
    F: Fn(usize, TaskRange) + Send + Sync,
{
    ThreadPool::new(topo.clone(), config.clone()).run(total, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sched::queue::QueueLayout;
    use crate::sched::victim::VictimStrategy;
    use crate::util::prop;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn host4() -> Topology {
        Topology::symmetric("test4", 2, 2, 1.5, 1.0)
    }

    fn count_items(topo: &Topology, config: &SchedConfig, total: usize) -> SchedReport {
        let hits: Vec<AtomicUsize> =
            (0..total).map(|_| AtomicUsize::new(0)).collect();
        let report = run_once(topo, config, total, |_w, range| {
            for i in range.iter() {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} executed != once");
        }
        report
    }

    #[test]
    fn centralized_executes_every_item_once() {
        let cfg = SchedConfig::default().with_scheme(Scheme::Gss);
        let r = count_items(&host4(), &cfg, 10_000);
        assert_eq!(r.total_items(), 10_000);
        assert_eq!(r.total_steals(), 0);
    }

    #[test]
    fn percore_with_stealing_executes_every_item_once() {
        for victim in VictimStrategy::ALL {
            let cfg = SchedConfig::default()
                .with_scheme(Scheme::Fac2)
                .with_layout(QueueLayout::PerCore)
                .with_victim(victim);
            let r = count_items(&host4(), &cfg, 5_000);
            assert_eq!(r.total_items(), 5_000, "{victim:?}");
        }
    }

    #[test]
    fn pergroup_executes_every_item_once() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Tss)
            .with_layout(QueueLayout::PerGroup)
            .with_victim(VictimStrategy::SeqPri);
        let r = count_items(&host4(), &cfg, 7_777);
        assert_eq!(r.total_items(), 7_777);
    }

    #[test]
    fn atomic_central_executes_every_item_once() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Mfsc)
            .with_layout(QueueLayout::Centralized { atomic: true });
        let r = count_items(&host4(), &cfg, 12_345);
        assert_eq!(r.total_items(), 12_345);
    }

    #[test]
    fn skewed_work_induces_steals_under_percore() {
        // All the cost in the first block: workers owning later blocks
        // finish instantly and must steal.
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Fac2)
            .with_layout(QueueLayout::PerCore)
            .with_victim(VictimStrategy::Seq);
        let r = run_once(&host4(), &cfg, 4_000, |_w, range| {
            for i in range.iter() {
                if i < 1000 {
                    std::hint::black_box((0..2_000).sum::<u64>());
                }
            }
        });
        assert!(
            r.total_steals() > 0,
            "skew must trigger stealing: {:?}",
            r.row()
        );
    }

    #[test]
    fn report_names_match_config() {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Pss)
            .with_layout(QueueLayout::PerCore)
            .with_victim(VictimStrategy::RndPri);
        let r = count_items(&host4(), &cfg, 100);
        assert_eq!(r.scheme, "PSS");
        assert_eq!(r.layout, "PERCORE");
        assert_eq!(r.victim, "RNDPRI");
    }

    #[test]
    fn prop_all_configs_execute_exactly_once() {
        prop::check("thread pool executes every item once", 25, |rng| {
            let scheme = *rng.choose(&Scheme::ALL);
            let layout = *rng.choose(&[
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ]);
            let victim = *rng.choose(&VictimStrategy::ALL);
            let total = rng.range(1, 5_000) as usize;
            let cfg = SchedConfig {
                scheme,
                layout,
                victim,
                seed: rng.next_u64(),
                stages: None,
                pls_swr: 0.5,
            };
            let hits: Vec<AtomicUsize> =
                (0..total).map(|_| AtomicUsize::new(0)).collect();
            run_once(&host4(), &cfg, total, |_w, range| {
                for i in range.iter() {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                prop::ensure(
                    h.load(Ordering::Relaxed) == 1,
                    format!(
                        "{scheme:?}/{layout:?}/{victim:?}: item {i} ran {}x",
                        h.load(Ordering::Relaxed)
                    ),
                )?;
            }
            Ok(())
        });
    }
}
