//! Vectorized execution engine (VEE): the DAPHNE runtime component that
//! turns (data, operator) into tasks and executes pipelines under a
//! scheduling configuration (Fig. 2).
//!
//! A pipeline is a sequence of [`Stage`]s with a barrier between stages
//! (each vectorized operator in DAPHNE is one scheduled parallel
//! region). Each stage's body is executed over row ranges chosen by the
//! configured partitioning/assignment; per-stage [`SchedReport`]s feed
//! the evaluation harness.

pub mod pipeline;

pub use pipeline::{Pipeline, PipelineReport, Stage};

use crate::config::SchedConfig;
use crate::sched::{worker, SchedReport, TaskRange};
use crate::topology::Topology;

/// The engine: topology + scheduling configuration.
#[derive(Debug, Clone)]
pub struct Vee {
    pub topo: Topology,
    pub sched: SchedConfig,
}

impl Vee {
    pub fn new(topo: Topology, sched: SchedConfig) -> Self {
        Vee { topo, sched }
    }

    /// Engine on the host topology with default (STATIC) scheduling.
    pub fn host_default() -> Self {
        Vee::new(Topology::host(), SchedConfig::default())
    }

    /// Execute one vectorized operator over `items` work items.
    pub fn execute<F>(&self, items: usize, body: F) -> SchedReport
    where
        F: Fn(usize, TaskRange) + Send + Sync,
    {
        worker::run_once(&self.topo, &self.sched, items, body)
    }

    /// Execute a pipeline stage-by-stage with barriers.
    pub fn run_pipeline(&self, pipeline: &Pipeline<'_>) -> PipelineReport {
        pipeline.run(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn execute_covers_items() {
        let vee = Vee::host_default();
        let count = AtomicUsize::new(0);
        let report = vee.execute(1234, |_w, r| {
            count.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1234);
        assert_eq!(report.total_items(), 1234);
    }
}
