//! The paper's second evaluation app (Listing 2): linear-regression
//! training on dense random data — natively, and through the AOT
//! JAX/Pallas artifacts over PJRT when `artifacts/` is built.
//!
//! ```sh
//! make artifacts && cargo run --release --example linear_regression
//! ```

use daphne_sched::apps::linreg::{self, LinregSpec};
use daphne_sched::config::SchedConfig;
use daphne_sched::runtime::{DeviceService, Runtime};
use daphne_sched::sched::Scheme;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn main() {
    let spec = LinregSpec { rows: 50_000, cols: 33, lambda: 1e-3, seed: 3 };
    let (x, y) = linreg::generate(&spec);
    let topo = Topology::host();
    println!(
        "design matrix {}x{}, host {} cores",
        x.rows,
        x.cols,
        topo.n_cores()
    );

    println!("\nnative execution, all schemes (one resident pool):");
    let vee = Vee::new(topo.clone(), SchedConfig::default());
    for scheme in Scheme::ALL {
        let cfg = SchedConfig::default().with_scheme(scheme);
        let r = linreg::run_with(&vee.with_config(cfg), &x, &y, spec.lambda)
            .unwrap();
        println!(
            "  {:<7} wall {:.4}s  rmse={:.4}",
            scheme.name(),
            r.report.total_time(),
            linreg::rmse(&x, &y, &r.beta)
        );
    }

    // -- PJRT path: the same pipeline through the AOT artifacts ---------
    if Runtime::default_dir().join("manifest.json").exists() {
        let (service, client) = DeviceService::start_default().unwrap();
        println!("\npjrt path (platform: {}):", service.platform);
        // artifact feature width is fixed; regenerate at that width
        let (_, d) = service.manifest.lr_block;
        let spec = LinregSpec { rows: 4096, cols: d + 1, lambda: 1e-3, seed: 3 };
        let (xp, yp) = linreg::generate(&spec);
        let cfg = SchedConfig::default().with_scheme(Scheme::Gss);
        let native =
            linreg::run_native(&xp, &yp, spec.lambda, &topo, &cfg).unwrap();
        let pjrt = linreg::run_pjrt(
            &xp,
            &yp,
            spec.lambda,
            &client,
            &service.manifest,
            &topo,
            &cfg,
        )
        .unwrap();
        let max_diff = native
            .beta
            .iter()
            .zip(&pjrt.beta)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "  native vs pjrt beta max |diff| = {max_diff:.2e} over {} coeffs",
            pjrt.beta.len()
        );
    } else {
        println!("\n(artifacts missing — run `make artifacts` for the PJRT path)");
    }
}
