//! Trace export: merge drained ring buffers into a Chrome trace-event
//! JSON file (loadable in Perfetto / `chrome://tracing`) and distill a
//! compact [`ObsSummary`] for the CLI.
//!
//! The Chrome format is the stable subset every viewer understands: a
//! top-level `traceEvents` array of objects with `ph` (phase), `pid`,
//! `tid`, `ts` (microseconds, f64) and `name`. We emit one `tid` lane
//! per worker (plus the control lane), `B`/`E` duration pairs for
//! chunk execution, `i` instants for everything else, `C` counter
//! tracks for backlog and admissions, and `M` metadata naming the
//! lanes. Written via `util::json` — no serializer dependency.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use crate::obs::trace::{tag_name, TraceEvent, TraceKind};
use crate::util::json::{self, Json};
use crate::util::stats::LatencyReservoir;

/// The process id used for every emitted event (single-process traces).
const TRACE_PID: f64 = 1.0;

/// Reservoir capacity of the per-tag queue-delay digest. Bounded so a
/// long soak cannot grow the summary; Algorithm R keeps the sample
/// uniform over everything seen.
const QUEUE_DELAY_RESERVOIR: usize = 4096;

/// Resolve a hash to a human-readable label: the interned string when
/// one exists (tags always; job names when a submission site interned
/// them), a short hex form otherwise. Shared with [`super::analyze`] /
/// [`super::report`] and with `sim`'s trace calibration, which must key
/// measured service times the same way the export names its slices.
pub fn label(hash: u64) -> String {
    if hash == 0 {
        return "(untagged)".to_string();
    }
    tag_name(hash).unwrap_or_else(|| format!("{:012x}", hash & 0xFFFF_FFFF_FFFF))
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn event_args(e: &TraceEvent) -> Json {
    let mut fields = vec![("job", Json::Num(e.job as f64))];
    if e.name_hash != 0 {
        fields.push(("name", Json::Str(label(e.name_hash))));
    }
    if e.tag_hash != 0 {
        fields.push(("tag", Json::Str(label(e.tag_hash))));
    }
    obj(fields)
}

/// Build the Chrome trace-event document for a drained event stream.
/// Events must be timestamp-sorted, which [`crate::obs::trace::drain`]
/// guarantees.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut out: Vec<Json> = Vec::new();

    // Lane metadata: name every tid that appears. The highest lane is
    // the control lane (submission-side events) by construction.
    let max_worker = events.iter().map(|e| e.worker).max();
    for w in events.iter().map(|e| e.worker).collect::<std::collections::BTreeSet<_>>() {
        let name = if Some(w) == max_worker && events.iter().any(|e| {
            e.worker == w
                && matches!(
                    e.kind,
                    TraceKind::Admit | TraceKind::Shed | TraceKind::Enqueue | TraceKind::Resize
                )
        }) {
            "control".to_string()
        } else {
            format!("worker {}", w)
        };
        out.push(obj(vec![
            ("ph", Json::Str("M".to_string())),
            ("name", Json::Str("thread_name".to_string())),
            ("pid", Json::Num(TRACE_PID)),
            ("tid", Json::Num(w as f64)),
            ("ts", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str(name))])),
        ]));
    }

    // Counter-track state, sampled at each contributing event.
    let (mut enq, mut done, mut admitted, mut shed) = (0u64, 0u64, 0u64, 0u64);
    // Last published width per pool (Resize packs pool id / width into
    // the name/tag slots — see `TraceKind::Resize`).
    let mut widths: BTreeMap<u64, u64> = BTreeMap::new();
    // Per-lane open-slice depth so an orphaned TaskEnd (its TaskStart
    // was overwritten in the ring) cannot emit an unbalanced `E`.
    let mut depth: BTreeMap<u32, u64> = BTreeMap::new();

    for e in events {
        let ts_us = e.ts_ns as f64 / 1_000.0;
        let base = |ph: &str| {
            vec![
                ("ph", Json::Str(ph.to_string())),
                ("pid", Json::Num(TRACE_PID)),
                ("tid", Json::Num(e.worker as f64)),
                ("ts", Json::Num(ts_us)),
            ]
        };
        match e.kind {
            TraceKind::TaskStart => {
                let mut f = base("B");
                f.push(("name", Json::Str(format!("run {}", label(e.name_hash)))));
                f.push(("cat", Json::Str("task".to_string())));
                f.push(("args", event_args(e)));
                out.push(obj(f));
                *depth.entry(e.worker).or_insert(0) += 1;
            }
            TraceKind::TaskEnd => {
                let d = depth.entry(e.worker).or_insert(0);
                if *d > 0 {
                    *d -= 1;
                    let mut f = base("E");
                    f.push(("name", Json::Str(format!("run {}", label(e.name_hash)))));
                    f.push(("cat", Json::Str("task".to_string())));
                    out.push(obj(f));
                }
            }
            TraceKind::Resize => {
                // The hash slots carry pool id / width, not labels.
                let mut f = base("i");
                f.push(("name", Json::Str("resize".to_string())));
                f.push(("cat", Json::Str("sched".to_string())));
                f.push(("s", Json::Str("t".to_string())));
                f.push((
                    "args",
                    obj(vec![
                        ("pool", Json::Num(e.name_hash as f64)),
                        ("width", Json::Num(e.tag_hash as f64)),
                    ]),
                ));
                out.push(obj(f));
            }
            kind => {
                let mut f = base("i");
                f.push(("name", Json::Str(kind.name().to_string())));
                f.push(("cat", Json::Str("sched".to_string())));
                f.push(("s", Json::Str("t".to_string())));
                f.push(("args", event_args(e)));
                out.push(obj(f));
            }
        }
        // Counter tracks: backlog (enqueued minus completed jobs) and
        // cumulative admission decisions.
        match e.kind {
            TraceKind::Enqueue | TraceKind::NodeComplete | TraceKind::Cancel => {
                match e.kind {
                    TraceKind::Enqueue => enq += 1,
                    _ => done += 1,
                }
                out.push(obj(vec![
                    ("ph", Json::Str("C".to_string())),
                    ("pid", Json::Num(TRACE_PID)),
                    ("name", Json::Str("backlog".to_string())),
                    ("ts", Json::Num(ts_us)),
                    ("args", obj(vec![("jobs", Json::Num(enq.saturating_sub(done) as f64))])),
                ]));
            }
            TraceKind::Admit | TraceKind::Shed => {
                match e.kind {
                    TraceKind::Admit => admitted += 1,
                    _ => shed += 1,
                }
                out.push(obj(vec![
                    ("ph", Json::Str("C".to_string())),
                    ("pid", Json::Num(TRACE_PID)),
                    ("name", Json::Str("admissions".to_string())),
                    ("ts", Json::Num(ts_us)),
                    ("args", obj(vec![
                        ("admitted", Json::Num(admitted as f64)),
                        ("shed", Json::Num(shed as f64)),
                    ])),
                ]));
            }
            TraceKind::Resize => {
                widths.insert(e.name_hash, e.tag_hash);
                out.push(obj(vec![
                    ("ph", Json::Str("C".to_string())),
                    ("pid", Json::Num(TRACE_PID)),
                    ("name", Json::Str("pool_width".to_string())),
                    ("ts", Json::Num(ts_us)),
                    (
                        "args",
                        Json::Obj(
                            widths
                                .iter()
                                .map(|(p, w)| (format!("pool{}", p), Json::Num(*w as f64)))
                                .collect(),
                        ),
                    ),
                ]));
            }
            _ => {}
        }
    }

    obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Serialize a drained event stream to `path` as Chrome trace-event
/// JSON. Load the file in <https://ui.perfetto.dev> or
/// `chrome://tracing`.
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> io::Result<()> {
    fs::write(path, json::to_string(&chrome_trace_json(events)))
}

/// Per-tag queue-delay percentiles (first `Dispatch` minus `Enqueue`
/// per job), reservoir-sampled with the same
/// [`LatencyReservoir`]/linear-interpolation semantics `figure` and
/// `serve` report — so the CLI summary and the JSON report quote
/// percentiles comparable with every other surface in the repo.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QueueDelayStats {
    /// Jobs with both an `Enqueue` and a `Dispatch` in the stream.
    pub jobs: u64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

/// Compact digest of a drained trace, printed by the CLI after traced
/// runs: steal efficiency, park/unpark churn, and per-tag queue-delay
/// percentiles.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsSummary {
    pub events: usize,
    pub steals: u64,
    pub failed_steals: u64,
    pub parks: u64,
    pub unparks: u64,
    /// tag hash -> reservoir-backed delay percentiles.
    pub queue_delay: BTreeMap<u64, QueueDelayStats>,
    /// Summed `WorkerStats.queue_wait` (seconds) when the caller has a
    /// `SchedReport` in hand — see [`ObsSummary::with_queue_wait`].
    pub queue_wait_secs: Option<f64>,
}

impl ObsSummary {
    pub fn from_events(events: &[TraceEvent]) -> ObsSummary {
        let mut s = ObsSummary { events: events.len(), ..ObsSummary::default() };
        // (tag, job) -> (enqueue ts, first dispatch ts)
        let mut jobs: BTreeMap<(u64, u64), (Option<u64>, Option<u64>)> = BTreeMap::new();
        for e in events {
            match e.kind {
                TraceKind::Steal => s.steals += 1,
                TraceKind::FailedSteal => s.failed_steals += 1,
                TraceKind::Park => s.parks += 1,
                TraceKind::Unpark => s.unparks += 1,
                TraceKind::Enqueue => {
                    let entry = jobs.entry((e.tag_hash, e.job)).or_default();
                    entry.0.get_or_insert(e.ts_ns);
                }
                TraceKind::Dispatch => {
                    let entry = jobs.entry((e.tag_hash, e.job)).or_default();
                    entry.1.get_or_insert(e.ts_ns);
                }
                _ => {}
            }
        }
        let mut reservoirs: BTreeMap<u64, LatencyReservoir> = BTreeMap::new();
        for ((tag, _job), (enq, disp)) in jobs {
            if let (Some(e), Some(d)) = (enq, disp) {
                reservoirs
                    .entry(tag)
                    .or_insert_with(|| {
                        // deterministic per-tag seed: summaries of the
                        // same stream are reproducible
                        LatencyReservoir::new(
                            QUEUE_DELAY_RESERVOIR,
                            0x9E37_79B9 ^ tag,
                        )
                    })
                    .record(d.saturating_sub(e) as f64);
            }
        }
        for (tag, r) in reservoirs {
            s.queue_delay.insert(
                tag,
                QueueDelayStats {
                    jobs: r.seen(),
                    p50_ns: r.p50(),
                    p99_ns: r.p99(),
                },
            );
        }
        s
    }

    /// Attach the summed per-worker `queue_wait` from a `SchedReport`,
    /// surfacing queue-acquisition overhead next to the event digest.
    pub fn with_queue_wait(mut self, secs: f64) -> ObsSummary {
        self.queue_wait_secs = Some(secs);
        self
    }

    /// `steals / (steals + failed_steals)`, or `None` when no steal
    /// rounds ran.
    pub fn steal_efficiency(&self) -> Option<f64> {
        let total = self.steals + self.failed_steals;
        (total > 0).then(|| self.steals as f64 / total as f64)
    }

    /// Stable JSON form for `BENCH_*.json` reports.
    pub fn to_json(&self) -> Json {
        let tags: Vec<Json> = self
            .queue_delay
            .iter()
            .map(|(tag, d)| {
                Json::Obj(
                    [
                        ("tag".to_string(), Json::Str(label(*tag))),
                        ("jobs".to_string(), Json::Num(d.jobs as f64)),
                        ("p50_ns".to_string(), Json::Num(d.p50_ns)),
                        ("p99_ns".to_string(), Json::Num(d.p99_ns)),
                    ]
                    .into_iter()
                    .collect(),
                )
            })
            .collect();
        let mut obj: BTreeMap<String, Json> = BTreeMap::from([
            ("events".to_string(), Json::Num(self.events as f64)),
            ("steals".to_string(), Json::Num(self.steals as f64)),
            (
                "failed_steals".to_string(),
                Json::Num(self.failed_steals as f64),
            ),
            ("parks".to_string(), Json::Num(self.parks as f64)),
            ("unparks".to_string(), Json::Num(self.unparks as f64)),
            ("queue_delay".to_string(), Json::Arr(tags)),
        ]);
        if let Some(eff) = self.steal_efficiency() {
            obj.insert("steal_efficiency".to_string(), Json::Num(eff));
        }
        if let Some(qw) = self.queue_wait_secs {
            obj.insert("queue_wait_secs".to_string(), Json::Num(qw));
        }
        Json::Obj(obj)
    }
}

impl fmt::Display for ObsSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "obs summary: {} events", self.events)?;
        match self.steal_efficiency() {
            Some(eff) => writeln!(
                f,
                "  steal efficiency: {:.1}% ({} hit / {} missed)",
                eff * 100.0,
                self.steals,
                self.failed_steals
            )?,
            None => writeln!(f, "  steal efficiency: n/a (no steal rounds)")?,
        }
        writeln!(f, "  park/unpark churn: {} parks, {} unparks", self.parks, self.unparks)?;
        if let Some(qw) = self.queue_wait_secs {
            writeln!(f, "  worker queue_wait total: {:.6} s", qw)?;
        }
        if !self.queue_delay.is_empty() {
            writeln!(f, "  queue delay (enqueue -> first dispatch), per tag:")?;
            for (tag, d) in &self.queue_delay {
                writeln!(
                    f,
                    "    {:<12} jobs={} p50={:.3}ms p99={:.3}ms",
                    label(*tag),
                    d.jobs,
                    d.p50_ns / 1e6,
                    d.p99_ns / 1e6
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::intern_tag;

    fn ev(ts_ns: u64, worker: u32, kind: TraceKind, job: u64, tag_hash: u64) -> TraceEvent {
        TraceEvent { ts_ns, worker, kind, job, name_hash: 0, tag_hash }
    }

    #[test]
    fn summary_counts_steals_parks_and_queue_delay() {
        let tag = intern_tag("export-test");
        let events = vec![
            ev(0, 2, TraceKind::Enqueue, 1, tag),
            ev(5_000, 0, TraceKind::Dispatch, 1, tag),
            ev(6_000, 0, TraceKind::Dispatch, 1, tag), // later re-dispatch ignored
            ev(7_000, 1, TraceKind::Steal, 1, tag),
            ev(8_000, 1, TraceKind::FailedSteal, u64::MAX, 0),
            ev(9_000, 1, TraceKind::Park, u64::MAX, 0),
            ev(9_500, 1, TraceKind::Unpark, u64::MAX, 0),
            ev(10_000, 2, TraceKind::Enqueue, 2, tag),
            ev(2_010_000, 0, TraceKind::Dispatch, 2, tag),
        ];
        let s = ObsSummary::from_events(&events);
        assert_eq!(s.events, 9);
        assert_eq!((s.steals, s.failed_steals), (1, 1));
        assert_eq!((s.parks, s.unparks), (1, 1));
        assert_eq!(s.steal_efficiency(), Some(0.5));
        // delays: 5us (job 1, the 6us re-dispatch ignored) and 2ms
        // (job 2); linear interpolation over two samples
        let d = s.queue_delay.get(&tag).expect("tag stats");
        assert_eq!(d.jobs, 2);
        assert!((d.p50_ns - 1_002_500.0).abs() < 1e-6, "p50 {}", d.p50_ns);
        assert!((d.p99_ns - 1_980_050.0).abs() < 1e-6, "p99 {}", d.p99_ns);
        let rendered = format!("{}", s.clone().with_queue_wait(0.5));
        assert!(rendered.contains("export-test"));
        assert!(rendered.contains("jobs=2"));
        assert!(rendered.contains("queue_wait total: 0.500000 s"));
        let j = s.to_json();
        assert_eq!(j.get("events").and_then(|v| v.as_f64()), Some(9.0));
        let tags = j
            .get("queue_delay")
            .and_then(|v| v.as_arr())
            .expect("queue_delay array");
        assert_eq!(tags.len(), 1);
        assert_eq!(
            tags[0].get("tag").and_then(|v| v.as_str()),
            Some("export-test")
        );
        assert!(tags[0].get("p99_ns").is_some());
    }

    #[test]
    fn empty_summary_renders_without_panicking() {
        let s = ObsSummary::from_events(&[]);
        assert_eq!(s.steal_efficiency(), None);
        let rendered = format!("{}", s);
        assert!(rendered.contains("0 events"));
        assert!(rendered.contains("n/a"));
    }

    #[test]
    fn chrome_trace_round_trips_through_util_json() {
        let tag = intern_tag("chrome-test");
        let events = vec![
            ev(1_000, 2, TraceKind::Admit, 0, tag),
            ev(1_100, 2, TraceKind::Enqueue, 0, tag),
            ev(2_000, 0, TraceKind::Dispatch, 0, tag),
            ev(2_000, 0, TraceKind::TaskStart, 0, tag),
            ev(3_000, 0, TraceKind::TaskEnd, 0, tag),
            ev(3_500, 0, TraceKind::NodeComplete, 0, tag),
            ev(4_000, 2, TraceKind::Shed, 1, tag),
            // pool 0 resized to width 3 (pool/width ride the hash slots)
            ev(4_500, 2, TraceKind::Resize, u64::MAX, 3),
        ];
        let doc = json::parse(&json::to_string(&chrome_trace_json(&events))).expect("valid json");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        assert!(!evs.is_empty());
        for e in evs {
            for key in ["ph", "pid", "ts"] {
                assert!(e.get(key).is_some(), "every event carries {}", key);
            }
        }
        let phases: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert!(phases.contains(&"M"), "lane metadata present");
        assert!(phases.contains(&"B") && phases.contains(&"E"), "duration pair present");
        assert!(phases.contains(&"C"), "counter track present");
        assert!(phases.contains(&"i"), "instants present");
        // B/E balance per tid
        assert_eq!(
            phases.iter().filter(|p| **p == "B").count(),
            phases.iter().filter(|p| **p == "E").count()
        );
        // control lane named: highest tid with admission events
        let control = evs.iter().find(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M")
                && e.get("tid").and_then(|t| t.as_f64()) == Some(2.0)
        });
        let name = control
            .and_then(|e| e.get("args"))
            .and_then(|a| a.get("name"))
            .and_then(|n| n.as_str());
        assert_eq!(name, Some("control"));
        // the Resize event feeds a pool_width counter track
        let width_track = evs
            .iter()
            .find(|e| {
                e.get("ph").and_then(|p| p.as_str()) == Some("C")
                    && e.get("name").and_then(|n| n.as_str()) == Some("pool_width")
            })
            .expect("pool_width counter track");
        assert_eq!(
            width_track
                .get("args")
                .and_then(|a| a.get("pool0"))
                .and_then(|v| v.as_f64()),
            Some(3.0)
        );
    }

    #[test]
    fn orphaned_task_end_does_not_emit_unbalanced_e() {
        let events = vec![ev(1_000, 0, TraceKind::TaskEnd, 0, 0)];
        let doc = chrome_trace_json(&events);
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
        assert!(evs
            .iter()
            .all(|e| e.get("ph").and_then(|p| p.as_str()) != Some("E")));
    }
}
