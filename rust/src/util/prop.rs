//! Minimal property-testing harness (proptest is not in the vendored
//! crate set). Runs a property over many seeded random cases and reports
//! the failing seed so a case replays deterministically:
//!
//! ```no_run
//! use daphne_sched::util::{prop, Rng};
//! prop::check("sum is commutative", 200, |rng: &mut Rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     prop::ensure(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use super::rng::Rng;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Succeed/fail helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `property`; panic with the failing seed on
/// the first violation. Base seed is derived from the property name so
/// adding properties doesn't reshuffle existing ones.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed \
                 {seed:#x}): {msg}"
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always true", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always false", 10, |_| ensure(false, "nope"));
    }

    #[test]
    fn cases_are_deterministic_per_name() {
        let mut a = Vec::new();
        check("det", 5, |rng| {
            a.push(rng.next_u64());
            Ok(())
        });
        let mut b = Vec::new();
        check("det", 5, |rng| {
            b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(a, b);
    }
}
