//! Post-hoc trace analysis: critical-path extraction with per-node
//! attribution and a per-worker utilization waterfall.
//!
//! Everything here is computed from the drained [`TraceEvent`] stream
//! alone — the same stream both the real executor and the DES emit
//! (the DES in virtual time via `trace::record_at`), so one analysis
//! answers "where did the makespan go" for either engine.
//!
//! **Span reconstruction.** Per node (keyed by `name_hash`): first
//! `Enqueue` opens the span, first `Dispatch` splits queueing from
//! execution, paired `TaskStart`/`TaskEnd` per worker accumulate pure
//! service time, `Steal` events and the set of executing workers mark
//! steal-induced migration, and the last `NodeComplete` closes it. A
//! `Cancel` without a completion marks the span cancelled; cancelled
//! spans never join the critical path.
//!
//! **Critical-path recovery.** Both engines record a parent's
//! `NodeComplete` *before* the dependent's `Enqueue`, so the chain that
//! bounded the makespan is recoverable without the graph: walk back
//! from the last-completing node, binding each node to the
//! latest-completing span whose `NodeComplete` is at or before the
//! node's `Enqueue`. In the DES that inequality is exact equality and
//! the per-node spans tile the makespan; on a real trace residual gaps
//! show up as `1 - crit_ratio`. When the caller has the graph's edges,
//! [`Analysis::from_events_with_edges`] restricts the walk to true
//! parents.
//!
//! Layering: like the rest of `obs` this module never reads `sched`
//! internals; it may additionally read `sim` *public* replay outcomes
//! (repolint `layering-obs`) so figure code can report the DES's own
//! critical path via [`critical_span_ratio`].

use std::collections::{BTreeMap, BTreeSet};

use crate::obs::export::label;
use crate::obs::trace::{TraceEvent, TraceKind, NO_JOB};
use crate::sim::GraphSimOutcome;
use crate::util::json::Json;

/// Reconstructed lifetime of one graph node, nanoseconds since trace
/// start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSpan {
    pub name_hash: u64,
    /// Interned name when known, short hex otherwise (see
    /// [`crate::obs::export`]'s label rules).
    pub label: String,
    pub enqueue_ns: u64,
    /// First `Dispatch` — absent for spans that never started.
    pub dispatch_ns: Option<u64>,
    /// Last `NodeComplete` — absent for cancelled/unfinished spans.
    pub complete_ns: Option<u64>,
    /// Summed paired `TaskStart`→`TaskEnd` time across workers: pure
    /// service, excluding queueing and inter-chunk scheduling gaps.
    pub service_ns: u64,
    /// `Steal` events charged to this node.
    pub steals: u64,
    /// Distinct workers that executed chunks of this node.
    pub workers: usize,
    pub cancelled: bool,
}

impl NodeSpan {
    fn new(name_hash: u64) -> NodeSpan {
        NodeSpan {
            name_hash,
            label: label(name_hash),
            enqueue_ns: u64::MAX,
            dispatch_ns: None,
            complete_ns: None,
            service_ns: 0,
            steals: 0,
            workers: 0,
            cancelled: false,
        }
    }

    /// Time spent waiting for the first worker: `Dispatch - Enqueue`.
    pub fn queue_ns(&self) -> u64 {
        self.dispatch_ns
            .map(|d| d.saturating_sub(self.enqueue_ns))
            .unwrap_or(0)
    }

    /// Time from first dispatch to completion (service plus chunk
    /// scheduling plus any stranding on the node's own tail).
    pub fn exec_ns(&self) -> u64 {
        match (self.dispatch_ns, self.complete_ns) {
            (Some(d), Some(c)) => c.saturating_sub(d),
            _ => 0,
        }
    }

    /// Whole span, `NodeComplete - Enqueue`.
    pub fn span_ns(&self) -> u64 {
        self.complete_ns
            .map(|c| c.saturating_sub(self.enqueue_ns))
            .unwrap_or(0)
    }

    /// Did chunks of this node run on more than one worker (the
    /// signature of steal-induced migration)?
    pub fn migrated(&self) -> bool {
        self.workers > 1
    }
}

/// One worker's share of the utilization waterfall.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerLane {
    pub worker: u32,
    /// Summed paired `TaskStart`→`TaskEnd` time.
    pub busy_ns: u64,
    /// Summed `Park`→`Unpark` time (an unmatched trailing `Park` is
    /// charged until the last event in the stream).
    pub parked_ns: u64,
    pub tasks: u64,
    pub steals: u64,
    pub failed_steals: u64,
    pub parks: u64,
}

/// Critical-path attribution plus the per-worker waterfall for one
/// drained trace.
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// Every reconstructed node span, keyed by `name_hash`.
    pub spans: BTreeMap<u64, NodeSpan>,
    /// The chain that bounded the makespan, root first.
    pub critical_path: Vec<NodeSpan>,
    /// Last `NodeComplete` minus first `Enqueue` over all spans.
    pub makespan_ns: u64,
    /// Sum of critical-path spans (`queue + exec` per node). Equal to
    /// `makespan_ns` when the chain tiles the trace exactly (the DES
    /// guarantees it); the shortfall is unexplained residual.
    pub attributed_ns: u64,
    pub lanes: Vec<WorkerLane>,
}

impl Analysis {
    /// Analyze a drained, timestamp-sorted stream without graph edges
    /// (binding parents recovered from completion order — exact for DES
    /// streams).
    pub fn from_events(events: &[TraceEvent]) -> Analysis {
        Analysis::from_events_with_edges(events, &[])
    }

    /// Analyze with explicit `(parent, child)` edges (hashes as in
    /// `TraceEvent::name_hash`); the critical-path walk then only binds
    /// true parents.
    pub fn from_events_with_edges(
        events: &[TraceEvent],
        edges: &[(u64, u64)],
    ) -> Analysis {
        let mut a = Analysis::default();
        // worker -> (name_hash, TaskStart ts) of the open chunk
        let mut open: BTreeMap<u32, (u64, u64)> = BTreeMap::new();
        // worker -> Park ts of the open park interval
        let mut parked: BTreeMap<u32, u64> = BTreeMap::new();
        let mut node_workers: BTreeMap<u64, BTreeSet<u32>> = BTreeMap::new();
        let mut lanes: BTreeMap<u32, WorkerLane> = BTreeMap::new();
        let is_node = |e: &TraceEvent| e.name_hash != 0 && e.job != NO_JOB;
        for e in events {
            match e.kind {
                TraceKind::Enqueue if is_node(e) => {
                    let s = a
                        .spans
                        .entry(e.name_hash)
                        .or_insert_with(|| NodeSpan::new(e.name_hash));
                    s.enqueue_ns = s.enqueue_ns.min(e.ts_ns);
                }
                TraceKind::Dispatch if is_node(e) => {
                    let s = a
                        .spans
                        .entry(e.name_hash)
                        .or_insert_with(|| NodeSpan::new(e.name_hash));
                    s.dispatch_ns.get_or_insert(e.ts_ns);
                }
                TraceKind::NodeComplete if is_node(e) => {
                    let s = a
                        .spans
                        .entry(e.name_hash)
                        .or_insert_with(|| NodeSpan::new(e.name_hash));
                    // events are sorted: the last one seen is the max
                    s.complete_ns = Some(e.ts_ns);
                    s.enqueue_ns = s.enqueue_ns.min(e.ts_ns);
                }
                TraceKind::Cancel if e.name_hash != 0 => {
                    a.spans
                        .entry(e.name_hash)
                        .or_insert_with(|| NodeSpan::new(e.name_hash))
                        .cancelled = true;
                }
                TraceKind::Steal => {
                    if is_node(e) {
                        a.spans
                            .entry(e.name_hash)
                            .or_insert_with(|| NodeSpan::new(e.name_hash))
                            .steals += 1;
                    }
                    let l = lanes.entry(e.worker).or_default();
                    l.worker = e.worker;
                    l.steals += 1;
                }
                TraceKind::FailedSteal => {
                    let l = lanes.entry(e.worker).or_default();
                    l.worker = e.worker;
                    l.failed_steals += 1;
                }
                TraceKind::TaskStart => {
                    open.insert(e.worker, (e.name_hash, e.ts_ns));
                    if e.name_hash != 0 {
                        node_workers
                            .entry(e.name_hash)
                            .or_default()
                            .insert(e.worker);
                    }
                }
                TraceKind::TaskEnd => {
                    if let Some((nh, start)) = open.remove(&e.worker) {
                        let d = e.ts_ns.saturating_sub(start);
                        let l = lanes.entry(e.worker).or_default();
                        l.worker = e.worker;
                        l.busy_ns += d;
                        l.tasks += 1;
                        if let Some(s) = a.spans.get_mut(&nh) {
                            s.service_ns += d;
                        }
                    }
                }
                TraceKind::Park => {
                    parked.entry(e.worker).or_insert(e.ts_ns);
                    let l = lanes.entry(e.worker).or_default();
                    l.worker = e.worker;
                    l.parks += 1;
                }
                TraceKind::Unpark => {
                    if let Some(since) = parked.remove(&e.worker) {
                        let l = lanes.entry(e.worker).or_default();
                        l.worker = e.worker;
                        l.parked_ns += e.ts_ns.saturating_sub(since);
                    }
                }
                _ => {}
            }
        }
        let last_ts = events.last().map(|e| e.ts_ns).unwrap_or(0);
        for (w, since) in parked {
            let l = lanes.entry(w).or_default();
            l.worker = w;
            l.parked_ns += last_ts.saturating_sub(since);
        }
        for (nh, ws) in node_workers {
            if let Some(s) = a.spans.get_mut(&nh) {
                s.workers = ws.len();
            }
        }
        a.lanes = lanes.into_values().collect();

        let start = a
            .spans
            .values()
            .map(|s| s.enqueue_ns)
            .min()
            .unwrap_or(0);
        let end = a
            .spans
            .values()
            .filter_map(|s| s.complete_ns)
            .max()
            .unwrap_or(start);
        a.makespan_ns = end.saturating_sub(start);

        // child -> parents, when the caller supplied edges
        let mut in_edges: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for &(parent, child) in edges {
            in_edges.entry(child).or_default().push(parent);
        }

        // Walk back from the last-completing span, binding each node to
        // the latest-completing candidate at or before its Enqueue.
        let sink = a
            .spans
            .values()
            .filter(|s| s.complete_ns == Some(end) && !s.cancelled)
            .map(|s| s.name_hash)
            .next();
        let mut chain: Vec<u64> = Vec::new();
        let mut visited: BTreeSet<u64> = BTreeSet::new();
        let mut cur = sink;
        while let Some(h) = cur {
            visited.insert(h);
            chain.push(h);
            let enq = a.spans[&h].enqueue_ns;
            let candidates: Vec<u64> = match in_edges.get(&h) {
                Some(parents) => parents.clone(),
                None => a.spans.keys().copied().collect(),
            };
            cur = candidates
                .into_iter()
                .filter(|p| !visited.contains(p))
                .filter_map(|p| {
                    let s = a.spans.get(&p)?;
                    match s.complete_ns {
                        Some(c) if c <= enq && !s.cancelled => {
                            Some((c, p))
                        }
                        _ => None,
                    }
                })
                .max()
                .map(|(_, p)| p);
        }
        chain.reverse();
        a.critical_path =
            chain.iter().map(|h| a.spans[h].clone()).collect();
        a.attributed_ns =
            a.critical_path.iter().map(|s| s.span_ns()).sum();
        a
    }

    /// `attributed_ns / makespan_ns` — how much of the makespan the
    /// recovered chain explains (1.0 when the spans tile it exactly).
    pub fn crit_ratio(&self) -> f64 {
        if self.makespan_ns == 0 {
            return if self.critical_path.is_empty() { 0.0 } else { 1.0 };
        }
        self.attributed_ns as f64 / self.makespan_ns as f64
    }

    /// Human-readable breakdown for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let ms = |ns: u64| ns as f64 / 1e6;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "critical path: {} node(s), attributed {:.3} ms of {:.3} ms \
             makespan ({:.1}%)",
            self.critical_path.len(),
            ms(self.attributed_ns),
            ms(self.makespan_ns),
            self.crit_ratio() * 100.0
        );
        for s in &self.critical_path {
            let _ = writeln!(
                out,
                "  {:<16} queue={:>9.3}ms exec={:>9.3}ms \
                 service={:>9.3}ms steals={}{}",
                s.label,
                ms(s.queue_ns()),
                ms(s.exec_ns()),
                ms(s.service_ns),
                s.steals,
                if s.migrated() { " migrated" } else { "" }
            );
        }
        if !self.lanes.is_empty() {
            let _ = writeln!(out, "worker waterfall:");
            for l in &self.lanes {
                let _ = writeln!(
                    out,
                    "  w{:<3} busy={:>9.3}ms parked={:>9.3}ms tasks={:<6} \
                     steals={:<4} failed={:<4} parks={}",
                    l.worker,
                    ms(l.busy_ns),
                    ms(l.parked_ns),
                    l.tasks,
                    l.steals,
                    l.failed_steals,
                    l.parks
                );
            }
        }
        out
    }

    /// Stable JSON form for `BENCH_*.json` reports.
    pub fn to_json(&self) -> Json {
        let node = |s: &NodeSpan| {
            Json::Obj(
                [
                    ("name".to_string(), Json::Str(s.label.clone())),
                    (
                        "queue_ns".to_string(),
                        Json::Num(s.queue_ns() as f64),
                    ),
                    ("exec_ns".to_string(), Json::Num(s.exec_ns() as f64)),
                    (
                        "service_ns".to_string(),
                        Json::Num(s.service_ns as f64),
                    ),
                    ("steals".to_string(), Json::Num(s.steals as f64)),
                    ("migrated".to_string(), Json::Bool(s.migrated())),
                ]
                .into_iter()
                .collect(),
            )
        };
        let lane = |l: &WorkerLane| {
            Json::Obj(
                [
                    ("worker".to_string(), Json::Num(l.worker as f64)),
                    ("busy_ns".to_string(), Json::Num(l.busy_ns as f64)),
                    (
                        "parked_ns".to_string(),
                        Json::Num(l.parked_ns as f64),
                    ),
                    ("tasks".to_string(), Json::Num(l.tasks as f64)),
                    ("steals".to_string(), Json::Num(l.steals as f64)),
                    (
                        "failed_steals".to_string(),
                        Json::Num(l.failed_steals as f64),
                    ),
                    ("parks".to_string(), Json::Num(l.parks as f64)),
                ]
                .into_iter()
                .collect(),
            )
        };
        Json::Obj(
            [
                (
                    "makespan_ns".to_string(),
                    Json::Num(self.makespan_ns as f64),
                ),
                (
                    "attributed_ns".to_string(),
                    Json::Num(self.attributed_ns as f64),
                ),
                (
                    "crit_ratio".to_string(),
                    Json::Num(self.crit_ratio()),
                ),
                (
                    "nodes".to_string(),
                    Json::Arr(
                        self.critical_path.iter().map(node).collect(),
                    ),
                ),
                (
                    "workers".to_string(),
                    Json::Arr(self.lanes.iter().map(lane).collect()),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// The DES's own critical-path attribution as a ratio: summed spans of
/// the replay's [`GraphSimOutcome::critical_path`] nodes over its
/// makespan. This is the `crit=` column of the figures — computed from
/// the replay outcome directly, so figures stay valid with tracing off.
pub fn critical_span_ratio(out: &GraphSimOutcome) -> f64 {
    let mk = out.makespan();
    if mk <= 0.0 {
        return 0.0;
    }
    let sum: f64 = out
        .critical_path
        .iter()
        .filter_map(|name| out.node(name))
        .map(|n| (n.finish - n.start).max(0.0))
        .sum();
    (sum / mk).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::fnv1a;

    fn ev(
        ts_ns: u64,
        worker: u32,
        kind: TraceKind,
        job: u64,
        name: &str,
    ) -> TraceEvent {
        TraceEvent {
            ts_ns,
            worker,
            kind,
            job,
            name_hash: fnv1a(name),
            tag_hash: 0,
        }
    }

    #[test]
    fn chain_spans_tile_the_makespan() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(10, 0, TraceKind::Dispatch, 0, "a"),
            ev(10, 0, TraceKind::TaskStart, 0, "a"),
            ev(100, 0, TraceKind::TaskEnd, 0, "a"),
            ev(100, 9, TraceKind::NodeComplete, 0, "a"),
            ev(100, 9, TraceKind::Enqueue, 1, "b"),
            ev(120, 1, TraceKind::Dispatch, 1, "b"),
            ev(120, 1, TraceKind::TaskStart, 1, "b"),
            ev(300, 1, TraceKind::TaskEnd, 1, "b"),
            ev(300, 9, TraceKind::NodeComplete, 1, "b"),
        ];
        let a = Analysis::from_events(&events);
        assert_eq!(a.makespan_ns, 300);
        assert_eq!(a.attributed_ns, 300);
        assert_eq!(a.crit_ratio(), 1.0);
        let names: Vec<&str> =
            a.critical_path.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(names.len(), 2);
        let (first, second) = (&a.critical_path[0], &a.critical_path[1]);
        assert_eq!((first.queue_ns(), first.exec_ns()), (10, 90));
        assert_eq!((second.queue_ns(), second.exec_ns()), (20, 180));
        assert_eq!(second.service_ns, 180);
        // waterfall: each worker served exactly its chunk
        let w0 =
            a.lanes.iter().find(|l| l.worker == 0).expect("lane 0");
        let w1 =
            a.lanes.iter().find(|l| l.worker == 1).expect("lane 1");
        assert_eq!((w0.busy_ns, w0.tasks), (90, 1));
        assert_eq!((w1.busy_ns, w1.tasks), (180, 1));
    }

    #[test]
    fn diamond_picks_the_heavy_branch() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(0, 0, TraceKind::Dispatch, 0, "a"),
            ev(50, 9, TraceKind::NodeComplete, 0, "a"),
            ev(50, 9, TraceKind::Enqueue, 1, "b"),
            ev(50, 9, TraceKind::Enqueue, 2, "c"),
            ev(50, 0, TraceKind::Dispatch, 1, "b"),
            ev(60, 1, TraceKind::Dispatch, 2, "c"),
            ev(100, 9, TraceKind::NodeComplete, 1, "b"),
            ev(200, 9, TraceKind::NodeComplete, 2, "c"),
            ev(200, 9, TraceKind::Enqueue, 3, "d"),
            ev(210, 0, TraceKind::Dispatch, 3, "d"),
            ev(260, 9, TraceKind::NodeComplete, 3, "d"),
        ];
        let a = Analysis::from_events(&events);
        assert_eq!(a.makespan_ns, 260);
        let names: Vec<&str> =
            a.critical_path.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(names.len(), 3, "{names:?}");
        // the light branch "b" (done at 100) is not on the path; the
        // chain binds d to c (complete 200 == d's enqueue)
        assert_eq!(a.attributed_ns, 50 + 150 + 60);
        assert_eq!(a.crit_ratio(), 1.0);
        assert!(a
            .critical_path
            .iter()
            .all(|s| s.name_hash != fnv1a("b")));
    }

    #[test]
    fn explicit_edges_override_the_completion_heuristic() {
        // unrelated node u completes at 150, exactly child x's enqueue;
        // without edges the walk binds x to u, with edges it binds the
        // true parent p (complete 100)
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "p"),
            ev(0, 9, TraceKind::Enqueue, 1, "u"),
            ev(100, 9, TraceKind::NodeComplete, 0, "p"),
            ev(150, 9, TraceKind::NodeComplete, 1, "u"),
            ev(150, 9, TraceKind::Enqueue, 2, "x"),
            ev(160, 0, TraceKind::Dispatch, 2, "x"),
            ev(220, 9, TraceKind::NodeComplete, 2, "x"),
        ];
        let heuristic = Analysis::from_events(&events);
        assert_eq!(heuristic.critical_path[0].name_hash, fnv1a("u"));
        let edges = [(fnv1a("p"), fnv1a("x"))];
        let exact = Analysis::from_events_with_edges(&events, &edges);
        let names: Vec<u64> = exact
            .critical_path
            .iter()
            .map(|s| s.name_hash)
            .collect();
        assert_eq!(names, vec![fnv1a("p"), fnv1a("x")]);
    }

    #[test]
    fn stolen_task_migration_is_attributed() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "s"),
            ev(10, 0, TraceKind::Dispatch, 0, "s"),
            ev(10, 0, TraceKind::TaskStart, 0, "s"),
            ev(50, 0, TraceKind::TaskEnd, 0, "s"),
            ev(50, 1, TraceKind::Steal, 0, "s"),
            ev(50, 1, TraceKind::TaskStart, 0, "s"),
            ev(90, 1, TraceKind::TaskEnd, 0, "s"),
            ev(90, 9, TraceKind::NodeComplete, 0, "s"),
        ];
        let a = Analysis::from_events(&events);
        let s = &a.critical_path[0];
        assert_eq!(s.steals, 1);
        assert!(s.migrated());
        assert_eq!(s.service_ns, 80);
        assert_eq!(a.attributed_ns, 90);
        assert_eq!(a.crit_ratio(), 1.0);
        let w1 =
            a.lanes.iter().find(|l| l.worker == 1).expect("lane 1");
        assert_eq!(w1.steals, 1);
    }

    #[test]
    fn cancelled_branch_stays_off_the_critical_path() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(40, 9, TraceKind::NodeComplete, 0, "a"),
            ev(40, 9, TraceKind::Enqueue, 1, "b"),
            ev(40, 9, TraceKind::Enqueue, 2, "c"),
            ev(60, 9, TraceKind::Cancel, 1, "b"),
            ev(140, 9, TraceKind::NodeComplete, 2, "c"),
            ev(140, 9, TraceKind::Enqueue, 3, "d"),
            ev(200, 9, TraceKind::NodeComplete, 3, "d"),
        ];
        let a = Analysis::from_events(&events);
        let b = &a.spans[&fnv1a("b")];
        assert!(b.cancelled);
        assert!(b.complete_ns.is_none());
        assert!(a
            .critical_path
            .iter()
            .all(|s| s.name_hash != fnv1a("b")));
        assert_eq!(a.critical_path.len(), 3, "a -> c -> d");
        assert_eq!(a.attributed_ns, a.makespan_ns);
    }

    #[test]
    fn park_intervals_and_empty_streams() {
        let a = Analysis::from_events(&[]);
        assert_eq!(a.makespan_ns, 0);
        assert!(a.critical_path.is_empty());
        assert_eq!(a.crit_ratio(), 0.0);

        let events = vec![
            ev(0, 0, TraceKind::Park, NO_JOB, ""),
            ev(500, 0, TraceKind::Unpark, NO_JOB, ""),
            ev(700, 1, TraceKind::Park, NO_JOB, ""),
            ev(900, 0, TraceKind::FailedSteal, NO_JOB, ""),
        ];
        let a = Analysis::from_events(&events);
        let w0 =
            a.lanes.iter().find(|l| l.worker == 0).expect("lane 0");
        assert_eq!((w0.parked_ns, w0.parks), (500, 1));
        assert_eq!(w0.failed_steals, 1);
        // trailing park runs to the last event
        let w1 =
            a.lanes.iter().find(|l| l.worker == 1).expect("lane 1");
        assert_eq!(w1.parked_ns, 200);
    }

    #[test]
    fn render_and_json_cover_the_breakdown() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "solo"),
            ev(10, 0, TraceKind::Dispatch, 0, "solo"),
            ev(10, 0, TraceKind::TaskStart, 0, "solo"),
            ev(110, 0, TraceKind::TaskEnd, 0, "solo"),
            ev(110, 9, TraceKind::NodeComplete, 0, "solo"),
        ];
        let a = Analysis::from_events(&events);
        let rendered = a.render();
        assert!(rendered.contains("critical path: 1 node(s)"));
        assert!(rendered.contains("worker waterfall"));
        let j = a.to_json();
        assert_eq!(
            j.get("makespan_ns").and_then(|v| v.as_f64()),
            Some(110.0)
        );
        let nodes =
            j.get("nodes").and_then(|v| v.as_arr()).expect("nodes");
        assert_eq!(nodes.len(), 1);
        assert!(nodes[0].get("queue_ns").is_some());
        assert!(j.get("workers").is_some());
    }
}
