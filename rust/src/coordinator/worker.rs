//! DaphneSched worker daemon (Fig. 5 right-hand side): listens for the
//! coordinator, stores inputs as they arrive, and executes shipped code
//! with its local shared-memory DaphneSched.
//!
//! The daemon's [`Vee`] fronts one persistent executor, so its worker
//! pool is spawned once at daemon start and reused across every
//! coordinator connection and every `CcIterate`/`RunScript` request.

use std::collections::BTreeMap;
use std::io::{self, BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use super::proto::{read_msg, write_msg, Msg};
use crate::matrix::CsrMatrix;
use crate::util::DisjointMut;
use crate::vee::Vee;

/// Stored worker inputs.
#[derive(Default)]
struct Store {
    dense: BTreeMap<String, (usize, usize, Vec<f32>)>,
    sparse: BTreeMap<String, (usize, Arc<CsrMatrix>)>, // (row_offset, block)
}

/// Serve one coordinator connection until `Shutdown`/EOF. Returns the
/// number of messages handled.
pub fn serve_connection(stream: TcpStream, vee: &Vee) -> io::Result<usize> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    write_msg(
        &mut writer,
        &Msg::Hello { cores: vee.topo.n_cores() as u32 },
    )?;

    let mut store = Store::default();
    let mut handled = 0usize;
    loop {
        let msg = match read_msg(&mut reader) {
            Ok(m) => m,
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        };
        handled += 1;
        match msg {
            Msg::Dense { name, rows, cols, data } => {
                store
                    .dense
                    .insert(name, (rows as usize, cols as usize, data));
                write_msg(&mut writer, &Msg::Ok)?;
            }
            Msg::SparseBlock { name, row_offset, rows, cols, indptr, indices } => {
                let block = CsrMatrix {
                    rows: rows as usize,
                    cols: cols as usize,
                    indptr: indptr.iter().map(|&p| p as usize).collect(),
                    indices,
                    vals: None,
                };
                store
                    .sparse
                    .insert(name, (row_offset as usize, Arc::new(block)));
                write_msg(&mut writer, &Msg::Ok)?;
            }
            Msg::CcIterate => {
                let reply = cc_iterate(&store, vee);
                write_msg(&mut writer, &reply)?;
            }
            Msg::RunScript { script, params } => {
                let params: BTreeMap<String, String> =
                    params.into_iter().collect();
                let reply = match crate::dsl::run_script(&script, &params, vee)
                {
                    Ok(out) => {
                        // convention: result variable named `result`,
                        // else the scheduled time alone is returned
                        let data = out
                            .mat("result")
                            .map(|m| m.data.clone())
                            .unwrap_or_default();
                        Msg::Result {
                            name: "result".into(),
                            scheduled_time: out.scheduled_time(),
                            data,
                        }
                    }
                    Err(e) => Msg::Error { message: e },
                };
                write_msg(&mut writer, &reply)?;
            }
            Msg::Shutdown => break,
            other => {
                write_msg(
                    &mut writer,
                    &Msg::Error {
                        message: format!("unexpected message {other:?}"),
                    },
                )?;
            }
        }
    }
    Ok(handled)
}

/// One locally-scheduled propagate pass over the stored block.
fn cc_iterate(store: &Store, vee: &Vee) -> Msg {
    let Some((row_offset, g)) = store.sparse.get("G") else {
        return Msg::Error { message: "no sparse input 'G'".into() };
    };
    let Some((_, _, c)) = store.dense.get("c") else {
        return Msg::Error { message: "no broadcast input 'c'".into() };
    };
    if c.len() != g.cols {
        return Msg::Error {
            message: format!("c has {} entries, G has {} cols", c.len(), g.cols),
        };
    }
    let rows = g.rows;
    let row_offset = *row_offset;
    let mut u = vec![0f32; rows];
    let view = DisjointMut::new(&mut u);
    let (gref, view) = (g.clone(), &view);
    let report = vee.execute(rows, move |_w, range| {
        let slice = view.slice_mut(range.start, range.end);
        for (off, r) in range.iter().enumerate() {
            // own id lives at global row index
            let mut m = c[row_offset + r];
            for &col in gref.row(r) {
                let v = c[col as usize];
                if v > m {
                    m = v;
                }
            }
            slice[off] = m;
        }
    });
    Msg::Result {
        name: "u".into(),
        scheduled_time: report.makespan,
        data: u,
    }
}

/// Listen on `addr` and serve coordinators until the process exits (or,
/// with `max_connections`, until that many have been served).
pub fn serve(
    listener: TcpListener,
    vee: Vee,
    max_connections: Option<usize>,
) -> io::Result<()> {
    let mut served = 0usize;
    for stream in listener.incoming() {
        serve_connection(stream?, &vee)?;
        served += 1;
        if let Some(max) = max_connections {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}
