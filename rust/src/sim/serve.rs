//! Open-loop serving replay: the DES mirror of [`crate::serve`].
//!
//! The tenant replay ([`super::graph::replay_tenants`]) models a
//! *closed* batch: every tenant is known up front and the run ends when
//! the last one finishes. A service under load is the opposite shape —
//! an open-loop generator emits requests at a target QPS regardless of
//! how fast the system drains them, so overload shows up as unbounded
//! queueing instead of a longer makespan. This module replays that
//! regime in virtual time:
//!
//! - [`arrival_times`] expands an
//!   [`ArrivalPattern`](crate::config::ArrivalPattern) (`burst` |
//!   `uniform` | `poisson`) into the request arrival offsets for a
//!   `qps × duration` window, deterministically from a seed. The real
//!   serving loop replays the *same* trace on the wall clock, which is
//!   what makes DES-vs-real admission agreement testable.
//! - [`replay_open_loop`] feeds those arrivals — each a small
//!   [`GraphShape`] request under the [`SERVE_TAG`] tag — through the
//!   multi-tenant event loop with batch tenants underneath, applying
//!   the [`AdmissionPolicy`] at every arrival exactly as the real
//!   loop's [`Session::try_submit_graph`](crate::sched::Session)
//!   would: backlog = admitted same-tag requests still in flight.
//! - [`ServeSimOutcome`] reports the serving metrics — attained QPS
//!   over the measurement window (arrivals after `warmup`), p50 / p99 /
//!   p999 latency from a seeded [`LatencyReservoir`], SLO attainment,
//!   shed counts, and the per-request accept/reject decision sequence.
//!
//! `figure serve` sweeps this replay over policy × admission on the
//! modelled machines; the `serve` CLI subcommand then confirms the
//! predicted ordering on the host executor.

use crate::config::{ArrivalPattern, GraphMode, SchedConfig};
use crate::sched::graph::GraphError;
use crate::sched::session::AdmissionPolicy;
use crate::sched::TenancyPolicy;
use crate::sim::model::CostModel;
use crate::topology::Topology;
use crate::util::stats::LatencyReservoir;
use crate::util::Rng;

use super::graph::{
    replay, replay_tenants_admitted, GraphShape, SimAdmission, TenantSpec,
};

/// Tenant tag of every open-loop request — the tag admission bounds and
/// the fair policy shares against the batch tenants. Shared with the
/// real serving loop so both count the same backlog.
pub const SERVE_TAG: &str = "serve";

/// Capacity of the per-run latency reservoir (both DES and real loop):
/// enough for exact percentiles on every bounded soak the figures and
/// CI run, bounded memory on long ones.
pub const RESERVOIR_CAPACITY: usize = 8192;

/// Deterministic request-arrival offsets (seconds from the serving
/// epoch) for an open-loop `qps × duration` window: `burst` releases
/// everything at 0 (the admission stress case), `uniform` spaces
/// arrivals evenly, `poisson` draws exponential inter-arrival gaps from
/// the seed. Always `ceil(qps × duration)` entries (the *offered* load;
/// a poisson trace is clamped to the window), sorted ascending.
pub fn arrival_times(
    pattern: ArrivalPattern,
    qps: f64,
    duration: f64,
    seed: u64,
) -> Vec<f64> {
    assert!(qps > 0.0 && duration > 0.0, "offered load must be positive");
    let n = (qps * duration).ceil() as usize;
    match pattern {
        ArrivalPattern::Burst => vec![0.0; n],
        ArrivalPattern::Uniform => {
            (0..n).map(|i| i as f64 / qps).collect()
        }
        ArrivalPattern::Poisson => {
            let mut rng = Rng::new(seed ^ 0x5E2F_E07A_9E1C_AB42);
            let mut t = 0.0;
            (0..n)
                .map(|_| {
                    t += rng.exponential(qps);
                    (t - 1.0 / qps).max(0.0).min(duration)
                })
                .collect()
        }
    }
}

/// One open-loop serving scenario: the request shape and rate, the
/// admission setting, and the batch tenants running underneath.
#[derive(Clone)]
pub struct OpenLoopSpec {
    /// The per-request pipeline instance (e.g. a linreg-inference
    /// prefix or a cc query), replayed once per arrival.
    pub request: GraphShape,
    /// Offered load: requests per (virtual) second.
    pub qps: f64,
    /// Length of the arrival window in seconds.
    pub duration: f64,
    /// Arrivals before this offset are served but not measured
    /// (reservoir warm-up of the real loop mirrored here).
    pub warmup: f64,
    /// Latency SLO in seconds (attainment = served requests within it).
    pub slo: f64,
    /// Admission applied at every request arrival.
    pub admission: AdmissionPolicy,
    /// Estimated service seconds per backlog entry (the `Shed` input).
    pub est_cost: f64,
    /// Arrival pattern of the generator.
    pub arrival: ArrivalPattern,
    /// Seed for the arrival trace and the latency reservoir.
    pub seed: u64,
    /// Priority of every request tenant (for `policy=priority`).
    pub priority: i64,
    /// Fair-share weight of the [`SERVE_TAG`] tag (for `policy=fair`).
    pub weight: u64,
    /// Batch tenants running underneath the request stream.
    pub batch: Vec<TenantSpec>,
}

/// Serving metrics of one [`replay_open_loop`] run (or, identically
/// shaped, of one real `serve` soak — see [`crate::serve`]).
#[derive(Debug, Clone)]
pub struct ServeSimOutcome {
    pub policy: TenancyPolicy,
    pub admission: AdmissionPolicy,
    /// Requests generated over the whole window (offered load).
    pub offered: usize,
    /// Requests arriving inside the measurement window (≥ warmup).
    pub measured: usize,
    /// Measured requests admitted and completed.
    pub served: usize,
    /// Measured requests rejected at admission.
    pub shed: usize,
    /// Served requests per second over the measurement window.
    pub attained_qps: f64,
    /// Latency percentiles over served measured requests (seconds).
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    /// Fraction of served measured requests within the SLO.
    pub slo_attainment: f64,
    /// Mean admission → first-dispatch delay of served measured
    /// requests.
    pub mean_queue_delay: f64,
    /// Virtual completion time of everything (batch included).
    pub makespan: f64,
    /// Accept/reject per request in arrival order (warmup included) —
    /// what the DES-vs-real agreement test compares.
    pub decisions: Vec<bool>,
}

impl ServeSimOutcome {
    /// Fraction of measured requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.measured == 0 {
            0.0
        } else {
            self.shed as f64 / self.measured as f64
        }
    }
}

/// Replay an open-loop serving window in virtual time: the request
/// stream of `spec` (admission-checked per arrival) over the batch
/// tenants, on `topo` under `policy`. The event loop, pick policies,
/// and admission rule are the same code paths `figure tenancy`
/// validated against the real executor, so the attained-QPS / tail
/// orderings this predicts are testable on the host (`serve` CLI).
pub fn replay_open_loop(
    spec: &OpenLoopSpec,
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
    policy: TenancyPolicy,
) -> Result<ServeSimOutcome, GraphError> {
    let arrivals = arrival_times(
        spec.arrival,
        spec.qps,
        spec.duration,
        spec.seed,
    );
    let offered = arrivals.len();

    // batch tenants first, then one tenant per request (spec order =
    // arrival order: arrival_times is sorted and the replay breaks
    // arrival ties by spec order)
    let mut tenants = spec.batch.clone();
    let first_req = tenants.len();
    for (i, &t) in arrivals.iter().enumerate() {
        tenants.push(
            TenantSpec::new(&format!("req{i}"), spec.request.clone(), t)
                .tag(SERVE_TAG)
                .priority(spec.priority)
                .weight(spec.weight),
        );
    }

    // isolated baselines: requests are identical, so replay the shape
    // once instead of per arrival (slowdowns are not a serving metric;
    // the baseline only feeds TenantOutcome bookkeeping)
    let request_isolated =
        replay(&spec.request, topo, default, costs, GraphMode::Dag)?
            .makespan();
    let mut isolated = Vec::with_capacity(tenants.len());
    for b in &spec.batch {
        isolated.push(
            replay(&b.shape, topo, default, costs, GraphMode::Dag)?
                .makespan(),
        );
    }
    isolated.extend(std::iter::repeat(request_isolated).take(offered));

    let adm = SimAdmission {
        policy: spec.admission,
        tag: SERVE_TAG.to_string(),
        est_cost: spec.est_cost,
    };
    let (out, decisions) = replay_tenants_admitted(
        &tenants,
        topo,
        default,
        costs,
        policy,
        &isolated,
        Some(&adm),
    )?;

    let mut reservoir =
        LatencyReservoir::new(RESERVOIR_CAPACITY, spec.seed ^ 0x7E5E);
    let mut queue_delays = Vec::new();
    let (mut measured, mut served, mut shed, mut within_slo) = (0, 0, 0, 0);
    let mut last_finish: f64 = 0.0;
    for (k, outcome) in out.tenants.iter().enumerate().skip(first_req) {
        let admitted = decisions[k];
        if outcome.arrival < spec.warmup {
            continue;
        }
        measured += 1;
        if !admitted {
            shed += 1;
            continue;
        }
        served += 1;
        let lat = outcome.latency();
        reservoir.record(lat);
        queue_delays.push(outcome.queueing_delay());
        if lat <= spec.slo {
            within_slo += 1;
        }
        last_finish = last_finish.max(outcome.finish);
    }
    // attained throughput: served requests over the span from the start
    // of the measurement window to the last served completion (the
    // drain tail counts — a backlogged system can't bank its queue)
    let span = (last_finish - spec.warmup).max(spec.duration - spec.warmup);
    let attained_qps =
        if span > 0.0 { served as f64 / span } else { 0.0 };

    Ok(ServeSimOutcome {
        policy,
        admission: spec.admission,
        offered,
        measured,
        served,
        shed,
        attained_qps,
        p50: reservoir.p50(),
        p99: reservoir.p99(),
        p999: reservoir.p999(),
        slo_attainment: if served == 0 {
            0.0
        } else {
            within_slo as f64 / served as f64
        },
        mean_queue_delay: crate::util::stats::mean(&queue_delays),
        makespan: out.makespan,
        decisions: decisions[first_req..].to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::graph::NodeModel;

    fn costs() -> CostModel {
        CostModel::recorded()
    }

    /// A small 3-node request chain (the linreg-inference prefix
    /// shape): colstats → stats → standardize.
    fn request_shape(items: usize, per_item: f64) -> GraphShape {
        GraphShape::new("linreg-infer")
            .node(NodeModel::uniform("colstats", items, per_item))
            .node(NodeModel::uniform("stats", 1, per_item).after("colstats"))
            .node(
                NodeModel::uniform("standardize", items, per_item)
                    .after("stats"),
            )
    }

    fn base_spec(admission: AdmissionPolicy) -> OpenLoopSpec {
        // 8 cores, request ~ 2*32+1 items * 1e-4 = ~6.5e-3 core-sec:
        // capacity ~ 8/6.5e-3 ≈ 1230 rps; offer well past it
        OpenLoopSpec {
            request: request_shape(32, 1e-4),
            qps: 2_000.0,
            duration: 0.1,
            warmup: 0.02,
            slo: 0.05,
            admission,
            est_cost: 6.5e-3,
            arrival: ArrivalPattern::Uniform,
            seed: 42,
            priority: 0,
            weight: 1,
            batch: Vec::new(),
        }
    }

    fn topo8() -> Topology {
        Topology::symmetric("t8", 1, 8, 1.0, 1.0)
    }

    #[test]
    fn arrival_times_shapes() {
        let burst = arrival_times(ArrivalPattern::Burst, 100.0, 0.5, 1);
        assert_eq!(burst.len(), 50);
        assert!(burst.iter().all(|&t| t == 0.0));
        let uni = arrival_times(ArrivalPattern::Uniform, 100.0, 0.5, 1);
        assert_eq!(uni.len(), 50);
        assert_eq!(uni[0], 0.0);
        assert!((uni[49] - 0.49).abs() < 1e-12);
        assert!(uni.windows(2).all(|w| w[0] <= w[1]), "sorted");
        let poi = arrival_times(ArrivalPattern::Poisson, 100.0, 0.5, 1);
        assert_eq!(poi.len(), 50);
        assert!(poi.iter().all(|&t| (0.0..=0.5).contains(&t)));
        assert!(poi.windows(2).all(|w| w[0] <= w[1]), "sorted");
        // deterministic per seed, distinct across seeds
        assert_eq!(
            poi,
            arrival_times(ArrivalPattern::Poisson, 100.0, 0.5, 1)
        );
        assert_ne!(
            poi,
            arrival_times(ArrivalPattern::Poisson, 100.0, 0.5, 2)
        );
    }

    #[test]
    fn open_admission_diverges_bounded_holds_the_tail() {
        let topo = topo8();
        let cfg = SchedConfig::fine_grained();
        let open = replay_open_loop(
            &base_spec(AdmissionPolicy::Open),
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        let bounded = replay_open_loop(
            &base_spec(AdmissionPolicy::Bounded { max_backlog: 4 }),
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        // open admits everything and the backlog (≈40% of 200 offered)
        // drives p99 far past the SLO
        assert_eq!(open.shed, 0);
        assert!(open.p99 > base_spec(AdmissionPolicy::Open).slo);
        // bounded sheds the excess and keeps the served tail inside it
        assert!(bounded.shed > 0);
        assert!(
            bounded.p99 <= base_spec(AdmissionPolicy::Open).slo,
            "bounded p99 {} vs slo",
            bounded.p99
        );
        assert!(bounded.slo_attainment >= 0.9);
        // latency decomposition carries through: queueing dominates
        // under open overload
        assert!(open.mean_queue_delay > bounded.mean_queue_delay);
        // both keep the machine busy: attained within ~2x of each other
        assert!(bounded.attained_qps > open.attained_qps * 0.5);
    }

    #[test]
    fn shed_behaves_like_a_derived_bound_and_is_deterministic() {
        let topo = topo8();
        let cfg = SchedConfig::fine_grained();
        let spec = base_spec(AdmissionPolicy::Shed { deadline: 0.026 });
        let a = replay_open_loop(
            &spec,
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fair,
        )
        .unwrap();
        let b = replay_open_loop(
            &spec,
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fair,
        )
        .unwrap();
        assert_eq!(a.decisions, b.decisions, "replays are deterministic");
        assert_eq!(a.p99, b.p99);
        // deadline 26ms / est 6.5ms => rejects at backlog >= 5
        assert!(a.shed > 0);
        assert!(a.p99 <= spec.slo, "shed p99 {} vs slo {}", a.p99, spec.slo);
        // offered - (served + shed) are the warmup arrivals
        assert_eq!(a.measured, a.served + a.shed);
        assert!(a.offered > a.measured);
    }

    #[test]
    fn burst_trace_admits_exactly_the_bound_first() {
        // all arrivals land before any completion, so Bounded{k} must
        // accept exactly the first k requests — the deterministic
        // sequence the DES-vs-real integration test relies on
        let topo = topo8();
        let cfg = SchedConfig::fine_grained();
        let spec = OpenLoopSpec {
            arrival: ArrivalPattern::Burst,
            warmup: 0.0,
            qps: 200.0,
            duration: 0.1, // 20 requests, all at t=0
            admission: AdmissionPolicy::Bounded { max_backlog: 5 },
            ..base_spec(AdmissionPolicy::Open)
        };
        let out = replay_open_loop(
            &spec,
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        assert_eq!(out.offered, 20);
        let expected: Vec<bool> =
            (0..20).map(|i| i < 5).collect();
        assert_eq!(out.decisions, expected);
        assert_eq!(out.served, 5);
        assert_eq!(out.shed, 15);
        // batch tenants under a foreign tag never count against the
        // serve backlog
        let mut with_batch = spec.clone();
        with_batch.batch = vec![TenantSpec::new(
            "batch",
            request_shape(64, 1e-4),
            0.0,
        )
        .tag("batch")];
        let out2 = replay_open_loop(
            &with_batch,
            &topo,
            &cfg,
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        assert_eq!(out2.decisions, expected);
    }
}
