//! Cost models for the DES: what each scheduler action costs in seconds.

use crate::topology::Topology;

/// Per-item execution costs of a workload, as a prefix-sum so any chunk
/// `[a, b)` costs `O(1)` to evaluate.
#[derive(Debug, Clone)]
pub struct Workload {
    /// `prefix[i]` = total cost of items `[0, i)`, seconds.
    prefix: Vec<f64>,
    /// Descriptive name for reports.
    pub name: String,
}

impl Workload {
    /// Build from per-item costs (seconds per item).
    pub fn from_costs(name: &str, costs: &[f64]) -> Self {
        let mut prefix = Vec::with_capacity(costs.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &c in costs {
            acc += c;
            prefix.push(acc);
        }
        Workload { prefix, name: name.to_string() }
    }

    /// Uniform per-item cost (the dense linear-regression shape).
    pub fn uniform(name: &str, items: usize, cost: f64) -> Self {
        Workload::from_costs(name, &vec![cost; items])
    }

    pub fn items(&self) -> usize {
        self.prefix.len() - 1
    }

    /// Total cost of items `[a, b)`.
    #[inline]
    pub fn chunk_cost(&self, a: usize, b: usize) -> f64 {
        self.prefix[b] - self.prefix[a]
    }

    /// Total sequential cost.
    pub fn total_cost(&self) -> f64 {
        *self.prefix.last().unwrap()
    }
}

/// Scheduler-action costs (seconds) plus locality factors. Defaults are
/// the recorded host calibration (see [`super::calibrate`]); benches can
/// re-measure at runtime.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Critical-section time of one lock-protected queue/partitioner
    /// access (lock + `getNextChunk` + unlock) **per worker sharing the
    /// queue**: lock handoff cost grows with the number of contenders
    /// (cache-line bouncing), so a centralized queue on P workers costs
    /// `P * queue_access` per pull while an owner-only per-core deque
    /// costs `1 *`. Serialized across workers — this scaling is what
    /// makes SS "explode" on the central queue and MFSC degrade under
    /// PERCPU, while leaving PERCORE's local pops cheap (§4).
    pub queue_access: f64,
    /// One `fetch_add` access on the atomic central queue. Still
    /// serialized (cache-line ownership migrates) but ~an order of
    /// magnitude cheaper.
    pub atomic_access: f64,
    /// Per-attempt overhead of probing a steal victim (on top of the
    /// victim queue's access cost).
    pub steal_overhead: f64,
    /// Fixed per-task dispatch overhead on the worker (task object
    /// setup, metrics), not serialized.
    pub dispatch: f64,
    /// Multiplier on execution cost for items homed on a remote NUMA
    /// domain (cold remote-socket reads).
    pub remote_exec_factor: f64,
    /// Multiplier on execution cost under the centralized layouts,
    /// where no pre-partitioning aligns blocks with sockets (pages
    /// interleave; on a 2-socket machine ~half the accesses are
    /// remote). 1.0 for single-socket topologies.
    pub interleave_factor: f64,
    /// OS/system interference: preemption-like events arrive per busy
    /// second at this rate (events/s). Dynamic schemes absorb a hit
    /// worker by routing later chunks elsewhere; STATIC's one-shot
    /// blocks take the delay on the critical path — this asymmetry is
    /// what the paper's STATIC-vs-dynamic margins measure on real
    /// machines. 0 disables.
    pub noise_rate: f64,
    /// Mean duration of one interference event (exponential), seconds.
    pub noise_duration: f64,
    /// Extra serialized time per queue access that does NOT scale with
    /// contenders (e.g. an app-level reduction merge performed under a
    /// shared lock at task completion). 0 for plain scheduling.
    pub serialized_extra: f64,
}

impl CostModel {
    /// Recorded host calibration of *this crate's* lean scheduler (see
    /// `calibrate::measure` and EXPERIMENTS.md §Calibration). Values in
    /// seconds. No interference noise — used by unit tests and perf
    /// work where determinism matters.
    pub fn recorded() -> Self {
        CostModel {
            queue_access: 20e-9,
            atomic_access: 9e-9,
            steal_overhead: 15e-9,
            dispatch: 10e-9,
            remote_exec_factor: 1.0, // set per topology by `for_topology`
            interleave_factor: 1.0,
            noise_rate: 0.0,
            noise_duration: 0.0,
            serialized_extra: 0.0,
        }
    }

    /// DAPHNE-runtime-like task-dispatch costs — the configuration the
    /// figures use. The paper's observed effects (SS "explodes" under
    /// central-queue locking; MFSC degrades under PERCPU contention)
    /// imply per-task costs of the DAPHNE runtime's queue path (lock,
    /// task-object allocation, future signaling), a few hundred ns —
    /// not this crate's bare 20 ns partitioner pull. Includes the
    /// OS-interference model active on any real multicore run.
    pub fn daphne_like() -> Self {
        CostModel {
            queue_access: 100e-9, // x contenders: 2us on a 20-core central queue
            atomic_access: 60e-9,
            steal_overhead: 500e-9,
            dispatch: 500e-9,
            remote_exec_factor: 1.0,
            interleave_factor: 1.0,
            noise_rate: 2000.0,
            noise_duration: 4e-6,
            serialized_extra: 0.0,
        }
    }

    /// Specialize locality factors for a machine model: remote execution
    /// costs `remote_numa_factor`; centralized layouts see the average
    /// of local and remote (page interleaving across `s` sockets).
    pub fn for_topology(mut self, topo: &Topology) -> Self {
        let s = topo.sockets.max(1) as f64;
        self.remote_exec_factor = topo.remote_numa_factor;
        self.interleave_factor =
            (1.0 + (s - 1.0) * topo.remote_numa_factor) / s;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_sums_answer_chunk_costs() {
        let w = Workload::from_costs("w", &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.items(), 4);
        assert_eq!(w.chunk_cost(0, 4), 10.0);
        assert_eq!(w.chunk_cost(1, 3), 5.0);
        assert_eq!(w.chunk_cost(2, 2), 0.0);
        assert_eq!(w.total_cost(), 10.0);
    }

    #[test]
    fn uniform_workload() {
        let w = Workload::uniform("u", 100, 0.5);
        assert_eq!(w.total_cost(), 50.0);
        assert_eq!(w.chunk_cost(10, 20), 5.0);
    }

    #[test]
    fn topology_factors() {
        let m = CostModel::recorded().for_topology(&Topology::broadwell20());
        assert_eq!(m.remote_exec_factor, 1.9);
        assert!((m.interleave_factor - 1.45).abs() < 1e-12);

        let single = Topology::symmetric("s", 1, 8, 1.0, 1.0);
        let m1 = CostModel::recorded().for_topology(&single);
        assert_eq!(m1.interleave_factor, 1.0);
    }
}
