//! Native CPU kernels over the matrix substrate — the DAPHNE runtime's
//! built-in operators. These are the reference implementations the VEE
//! uses on the host path (and against which the PJRT-artifact path is
//! validated in `rust/tests/`).

use super::csr::CsrMatrix;
use super::dense::DenseMatrix;

/// `u[r] = max(max_{c in row r} ids[c], ids_row[r])` over a row range of
/// a sparse adjacency — the CC inner step (Listing 1 line 13) on CSR.
/// This is the native hot kernel; per-row cost is `row_nnz(r)`.
pub fn cc_propagate_rows(
    g: &CsrMatrix,
    ids: &[f32],
    out: &mut [f32],
    row_start: usize,
    row_end: usize,
) {
    for r in row_start..row_end {
        let mut m = ids[r];
        for &c in g.row(r) {
            let v = ids[c as usize];
            if v > m {
                m = v;
            }
        }
        out[r] = m;
    }
}

/// Column sums and sums of squares over a row range (LR lines 8-9).
pub fn colstats_rows(
    x: &DenseMatrix,
    sum: &mut [f32],
    sumsq: &mut [f32],
    row_start: usize,
    row_end: usize,
) {
    for r in row_start..row_end {
        for (c, &v) in x.row(r).iter().enumerate() {
            sum[c] += v;
            sumsq[c] += v * v;
        }
    }
}

/// Standardize a row range in place (LR line 10).
pub fn standardize_rows(
    x: &mut DenseMatrix,
    mean: &[f32],
    std: &[f32],
    row_start: usize,
    row_end: usize,
) {
    for r in row_start..row_end {
        for (c, v) in x.row_mut(r).iter_mut().enumerate() {
            *v = (*v - mean[c]) / std[c];
        }
    }
}

/// `A += X[rows]^T X[rows]` over a row range (LR line 12). `a` is a
/// `cols x cols` row-major accumulator owned by the caller (per-task
/// partials are reduced by the VEE).
pub fn syrk_rows(
    x: &DenseMatrix,
    a: &mut [f32],
    row_start: usize,
    row_end: usize,
) {
    let d = x.cols;
    debug_assert_eq!(a.len(), d * d);
    for r in row_start..row_end {
        let row = x.row(r);
        for i in 0..d {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let arow = &mut a[i * d..(i + 1) * d];
            for (j, &xj) in row.iter().enumerate() {
                arow[j] += xi * xj;
            }
        }
    }
}

/// `b += X[rows]^T y[rows]` over a row range (LR line 15).
pub fn gemv_rows(
    x: &DenseMatrix,
    y: &[f32],
    b: &mut [f32],
    row_start: usize,
    row_end: usize,
) {
    debug_assert_eq!(b.len(), x.cols);
    for r in row_start..row_end {
        let yr = y[r];
        for (c, &v) in x.row(r).iter().enumerate() {
            b[c] += v * yr;
        }
    }
}

/// Dense Cholesky solve of `A x = b` for SPD `A` (LR line 16,
/// `solve(A, b)`). DAPHNE maps `solve` to LAPACK; here it is native —
/// A = XᵀX + λI is SPD by construction. f64 internally for stability.
pub fn cholesky_solve(a: &DenseMatrix, b: &[f32]) -> Result<Vec<f32>, String> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return Err(format!(
            "solve: shape mismatch A={}x{}, b={}",
            a.rows,
            a.cols,
            b.len()
        ));
    }
    // factor A = L L^T
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(format!("solve: not SPD at pivot {i} ({s})"));
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    // forward substitution L z = b
    let mut z = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l[i * n + k] * z[k];
        }
        z[i] = s / l[i * n + i];
    }
    // back substitution L^T x = z
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Ok(x.into_iter().map(|v| v as f32).collect())
}

/// Dense mat-vec `A v` (used by the DSL interpreter).
pub fn matvec(a: &DenseMatrix, v: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, v.len());
    (0..a.rows)
        .map(|r| a.row(r).iter().zip(v).map(|(x, y)| x * y).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn cc_propagate_matches_bruteforce() {
        let g = CsrMatrix::from_edges(4, 4, &[(0, 1), (1, 3), (2, 0), (3, 3)]);
        let ids = [1.0, 5.0, 2.0, 9.0];
        let mut out = [0.0; 4];
        cc_propagate_rows(&g, &ids, &mut out, 0, 4);
        // row0: max(ids[1], own 1) = 5; row1: max(ids[3], 5) = 9;
        // row2: max(ids[0], 2) = 2; row3: max(ids[3], 9) = 9
        assert_eq!(out, [5.0, 9.0, 2.0, 9.0]);
    }

    #[test]
    fn cc_propagate_partial_rows_only() {
        let g = CsrMatrix::from_edges(3, 3, &[(0, 2), (1, 2)]);
        let ids = [1.0, 1.0, 7.0];
        let mut out = [0.0; 3];
        cc_propagate_rows(&g, &ids, &mut out, 1, 2);
        assert_eq!(out, [0.0, 7.0, 0.0]); // only row 1 written
    }

    #[test]
    fn colstats_accumulates() {
        let x = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mut s = [0.0; 2];
        let mut sq = [0.0; 2];
        colstats_rows(&x, &mut s, &mut sq, 0, 2);
        assert_eq!(s, [4.0, 6.0]);
        assert_eq!(sq, [10.0, 20.0]);
    }

    #[test]
    fn syrk_matches_explicit_transpose_product() {
        let mut rng = Rng::new(3);
        let x = DenseMatrix::rand(20, 5, -1.0, 1.0, rng.next_u64());
        let mut a = vec![0f32; 25];
        syrk_rows(&x, &mut a, 0, 20);
        let xt = x.transpose();
        for i in 0..5 {
            for j in 0..5 {
                let want: f32 =
                    (0..20).map(|k| xt[(i, k)] * xt[(j, k)]).sum();
                assert!(
                    (a[i * 5 + j] - want).abs() < 1e-4,
                    "A[{i},{j}]={} want {want}",
                    a[i * 5 + j]
                );
            }
        }
    }

    #[test]
    fn gemv_matches_explicit() {
        let x = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = [10.0, 100.0];
        let mut b = [0.0; 2];
        gemv_rows(&x, &y, &mut b, 0, 2);
        assert_eq!(b, [310.0, 420.0]); // X^T y
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = [[4,2],[2,3]], b = [10, 9] -> x = [1.5, 2]
        let a = DenseMatrix::from_vec(2, 2, vec![4.0, 2.0, 2.0, 3.0]);
        let x = cholesky_solve(&a, &[10.0, 9.0]).unwrap();
        assert!((x[0] - 1.5).abs() < 1e-5 && (x[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = DenseMatrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        assert!(cholesky_solve(&a, &[1.0, 1.0]).is_err());
        let bad_shape = DenseMatrix::zeros(2, 3);
        assert!(cholesky_solve(&bad_shape, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn prop_cholesky_recovers_solution() {
        prop::check("cholesky solves planted SPD systems", 40, |rng| {
            let n = rng.range(1, 20) as usize;
            // A = M^T M + I is SPD
            let m = DenseMatrix::rand(n, n, -1.0, 1.0, rng.next_u64());
            let mut a = DenseMatrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += m[(k, i)] * m[(k, j)];
                    }
                    a[(i, j)] = s + if i == j { 1.0 } else { 0.0 };
                }
            }
            let x_true: Vec<f32> =
                (0..n).map(|_| rng.normal() as f32).collect();
            let b = matvec(&a, &x_true);
            let x = cholesky_solve(&a, &b).map_err(|e| e.to_string())?;
            for i in 0..n {
                prop::ensure(
                    (x[i] - x_true[i]).abs() < 1e-2,
                    format!("x[{i}]={} want {}", x[i], x_true[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_syrk_row_split_accumulates() {
        prop::check("syrk partials sum to whole", 30, |rng| {
            let rows = rng.range(2, 50) as usize;
            let cols = rng.range(1, 10) as usize;
            let x = DenseMatrix::rand(rows, cols, -1.0, 1.0, rng.next_u64());
            let split = rng.range(1, rows as u64) as usize;
            let mut whole = vec![0f32; cols * cols];
            syrk_rows(&x, &mut whole, 0, rows);
            let mut parts = vec![0f32; cols * cols];
            syrk_rows(&x, &mut parts, 0, split);
            syrk_rows(&x, &mut parts, split, rows);
            for (i, (a, b)) in whole.iter().zip(&parts).enumerate() {
                prop::ensure(
                    (a - b).abs() < 1e-3,
                    format!("idx {i}: {a} vs {b}"),
                )?;
            }
            Ok(())
        });
    }
}
