//! The discrete-event engine: drives the real scheduler components in
//! virtual time.
//!
//! Each worker is a state machine: *idle → acquiring → executing →
//! idle*. Idle events live in a min-heap keyed by virtual time. Queue
//! accesses serialize through a per-queue `free_at` horizon — lock
//! contention (and the cheaper atomic contention) *emerges* from workers
//! queuing at the critical section rather than from a fitted curve.
//!
//! Approximation note: a worker's whole acquisition sequence (own-queue
//! probe plus steal round) is processed at one event, so probe
//! interleaving across workers is resolved at acquisition granularity,
//! not per probe. Serialization windows are still respected via
//! `free_at`; the coarsening only affects which of two nearly-simultaneous
//! thieves wins a chunk, which is noise the seeds average out.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;

use super::model::{CostModel, Workload};
use crate::config::SchedConfig;
use crate::sched::metrics::{SchedReport, WorkerStats};
use crate::sched::partitioner::PartitionerOptions;
use crate::sched::queue::{self, Pull, QueueLayout, TaskSource};
use crate::sched::victim::VictimSelector;
use crate::topology::Topology;
use crate::util::Rng;

/// Result of one simulated execution.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub report: SchedReport,
    /// Virtual seconds each queue spent occupied (contention signal).
    pub queue_busy: Vec<f64>,
    /// Total acquisition events processed.
    pub acquisitions: usize,
}

impl SimOutcome {
    pub fn makespan(&self) -> f64 {
        self.report.makespan
    }
}

#[derive(Debug, PartialEq)]
pub(crate) struct Ev {
    pub(crate) t: f64,
    pub(crate) w: usize,
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap: earlier time first; ties by worker id for determinism
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.w.cmp(&self.w))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

/// Per-job virtual-time scheduling state: the real `TaskSource` plus
/// the cost bookkeeping (`free_at` horizons, per-queue access costs,
/// victim selectors, worker stats) for ONE scheduled job.
///
/// [`simulate`] drives a single `JobSim` to completion; the graph
/// replay ([`super::graph`]) keeps several alive at once — one per
/// active graph node — and lets workers scan them in activation order,
/// mirroring how the real executor multiplexes job-scoped sources over
/// one resident pool.
pub(crate) struct JobSim<'w> {
    costs: CostModel,
    source: Box<dyn TaskSource>,
    workload: &'w Workload,
    queue_socket: Vec<usize>,
    access_cost: Vec<f64>,
    no_affinity: bool,
    selectors: Vec<Option<VictimSelector>>,
    free_at: Vec<f64>,
    queue_busy: Vec<f64>,
    stats: Vec<WorkerStats>,
    noise_rng: Rng,
    scheme: &'static str,
    layout: &'static str,
    victim: &'static str,
    acquisitions: usize,
}

impl<'w> JobSim<'w> {
    pub(crate) fn new(
        topo: &Topology,
        config: &SchedConfig,
        workload: &'w Workload,
        costs: &CostModel,
    ) -> Self {
        let costs = costs.clone().for_topology(topo);
        let opts = PartitionerOptions {
            stages: config.stages,
            pls_swr: config.pls_swr,
            seed: config.seed,
        };
        let source = queue::build_source(
            config.layout,
            config.scheme,
            workload.items(),
            topo,
            &opts,
        );
        let n_queues = source.n_queues();
        let n = topo.n_cores();

        // Home socket of every queue (mirrors executor::queue_socket_of).
        let queue_socket: Vec<usize> = (0..n_queues)
            .map(|q| {
                if n_queues == n {
                    topo.socket_of(q)
                } else if n_queues == topo.sockets {
                    q
                } else {
                    0
                }
            })
            .collect();

        // Execution locality: only PERCPU's contiguous pre-partitioning
        // gives block affinity; the centralized queue and PERCORE's
        // globally-dealt chunks see interleaved memory (§4's explanation
        // of STATIC's Fig. 7a vs 8a vs 8b behaviour).
        let no_affinity = matches!(
            config.layout,
            QueueLayout::Centralized { .. } | QueueLayout::PerCore
        );
        // Lock handoff scales with the number of workers sharing the
        // queue (see CostModel::queue_access); the atomic fetch_add path
        // is flat. Handoff cost saturates once the lock convoy forms
        // (~15 waiters): beyond that, extra waiters queue up (modelled
        // by serialization) without lengthening the critical section.
        let contenders: Vec<f64> = {
            let mut counts = vec![0usize; n_queues];
            for w in 0..n {
                counts[source.queue_of(w)] += 1;
            }
            counts.iter().map(|&c| c.clamp(1, 15) as f64).collect()
        };
        let access_cost: Vec<f64> = (0..n_queues)
            .map(|q| match config.layout {
                QueueLayout::Centralized { atomic: true } => {
                    costs.atomic_access
                }
                _ => costs.queue_access * contenders[q],
            })
            .collect();

        let selectors: Vec<Option<VictimSelector>> = (0..n)
            .map(|w| {
                config.layout.steals().then(|| {
                    VictimSelector::new(
                        config.victim,
                        source.queue_of(w),
                        topo.socket_of(w),
                        queue_socket.clone(),
                        config.seed ^ (w as u64).wrapping_mul(0x9E37_79B9),
                    )
                })
            })
            .collect();

        JobSim {
            noise_rng: Rng::new(config.seed ^ 0x5EED_0153),
            free_at: vec![0f64; n_queues],
            queue_busy: vec![0f64; n_queues],
            stats: vec![WorkerStats::default(); n],
            scheme: config.scheme.name(),
            layout: config.layout.name(),
            victim: config.victim.name(),
            acquisitions: 0,
            costs,
            source,
            workload,
            queue_socket,
            access_cost,
            no_affinity,
            selectors,
        }
    }

    /// Serialized access to queue `q`; returns the access completion
    /// time.
    fn access(
        &mut self,
        q: usize,
        now: f64,
        extra: f64,
        my_socket: usize,
        remote_numa_factor: f64,
    ) -> f64 {
        let numa = if self.queue_socket[q] == my_socket {
            1.0
        } else {
            remote_numa_factor
        };
        let start = now.max(self.free_at[q]);
        let dur = self.access_cost[q] * numa + self.costs.serialized_extra + extra;
        self.free_at[q] = start + dur;
        self.queue_busy[q] += dur;
        start + dur
    }

    /// One acquisition attempt by worker `w` at `*now`: own-queue probe
    /// plus a steal round. Advances `*now` past the serialized queue
    /// accesses whether or not a chunk was obtained.
    pub(crate) fn try_acquire(
        &mut self,
        topo: &Topology,
        w: usize,
        now: &mut f64,
    ) -> Option<Pull> {
        self.acquisitions += 1;
        let my_socket = topo.socket_of(w);

        // 1) own queue
        let own_q = self.source.queue_of(w);
        let end = self.access(own_q, *now, 0.0, my_socket, topo.remote_numa_factor);
        let mut pull = self.source.pull_local(w);
        self.stats[w].queue_wait += end - *now;
        *now = end;

        // 2) steal round
        if pull.is_none() {
            // take the selector out so `self.access` stays callable
            let mut selector = self.selectors[w].take();
            if let Some(selector) = selector.as_mut() {
                for victim in selector.round() {
                    let end = self.access(
                        victim,
                        *now,
                        self.costs.steal_overhead,
                        my_socket,
                        topo.remote_numa_factor,
                    );
                    self.stats[w].queue_wait += end - *now;
                    *now = end;
                    pull = self.source.pull_from(victim, w);
                    if pull.is_some() {
                        break;
                    }
                    self.stats[w].failed_steals += 1;
                }
            }
            self.selectors[w] = selector;
        }
        pull
    }

    /// Execution time of an acquired chunk on worker `w` (locality
    /// factor by layout + queue home, plus modelled OS interference);
    /// updates the worker's busy/task/steal counters.
    pub(crate) fn exec_time(
        &mut self,
        topo: &Topology,
        w: usize,
        pull: &Pull,
    ) -> f64 {
        let my_socket = topo.socket_of(w);
        if pull.stolen {
            self.stats[w].steals += 1;
            self.stats[w].stolen_items += pull.task.len();
        }

        // locality factor depends on layout + homes
        let locality = if self.no_affinity {
            self.costs.interleave_factor
        } else if self.queue_socket[pull.queue] == my_socket {
            1.0
        } else {
            self.costs.remote_exec_factor
        };
        // speed_of folds in per-place factors, so a *flat* simulation of
        // a heterogeneous topology (e.g. the single-workload tuner on
        // hetero56) still models accelerator places at their own speed;
        // pool-scoped sub-topologies have the factor pre-folded into
        // core_speed and per-place speed 1.0, so this is identical
        // there.
        let mut exec = self.workload.chunk_cost(pull.task.start, pull.task.end)
            * locality
            / topo.speed_of(w)
            + self.costs.dispatch;
        // OS interference: Poisson preemption events over the chunk's
        // busy time, each stretching it by an exponential delay. A
        // dynamic scheme reroutes subsequent chunks around a hit
        // worker; STATIC's single block eats the delay on the critical
        // path.
        if self.costs.noise_rate > 0.0 {
            let lambda = self.costs.noise_rate * exec;
            // Poisson via sequential exponential arrivals (lambda is
            // small for realistic chunks).
            let mut budget = lambda;
            loop {
                let step = self.noise_rng.exponential(1.0);
                if step > budget {
                    break;
                }
                budget -= step;
                exec += self.noise_rng.exponential(1.0 / self.costs.noise_duration);
            }
        }
        self.stats[w].busy += exec;
        self.stats[w].tasks += 1;
        self.stats[w].items += pull.task.len();
        exec
    }

    /// Finalize the job into a [`SimOutcome`] with the given makespan.
    pub(crate) fn into_outcome(self, makespan: f64) -> SimOutcome {
        SimOutcome {
            report: SchedReport {
                scheme: self.scheme.to_string(),
                layout: self.layout.to_string(),
                victim: self.victim.to_string(),
                makespan,
                // a standalone simulated job is dispatched at t=0; graph
                // and tenant replays account queueing at their own level
                queue_delay: 0.0,
                per_worker: self.stats,
            },
            queue_busy: self.queue_busy,
            acquisitions: self.acquisitions,
        }
    }
}

/// Simulate scheduling `workload` with `config` on `topo`.
pub fn simulate(
    topo: &Topology,
    config: &SchedConfig,
    workload: &Workload,
    costs: &CostModel,
) -> SimOutcome {
    let mut job = JobSim::new(topo, config, workload, costs);
    let n = topo.n_cores();
    let mut heap: BinaryHeap<Ev> = (0..n).map(|w| Ev { t: 0.0, w }).collect();
    let mut makespan = 0f64;

    while let Some(Ev { t, w }) = heap.pop() {
        let mut now = t;
        match job.try_acquire(topo, w, &mut now) {
            None => makespan = makespan.max(now), // worker retires
            Some(pull) => {
                let exec = job.exec_time(topo, w, &pull);
                heap.push(Ev { t: now + exec, w });
            }
        }
    }

    job.into_outcome(makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sched::victim::VictimStrategy;
    use crate::util::prop;

    fn costs() -> CostModel {
        CostModel::recorded()
    }

    fn cfg(scheme: Scheme) -> SchedConfig {
        SchedConfig::default().with_scheme(scheme)
    }

    #[test]
    fn all_items_execute_exactly_once() {
        let topo = Topology::broadwell20();
        let w = Workload::uniform("u", 10_000, 1e-6);
        let out = simulate(&topo, &cfg(Scheme::Gss), &w, &costs());
        assert_eq!(out.report.total_items(), 10_000);
    }

    #[test]
    fn uniform_work_static_is_near_perfect() {
        // N divisible by P, uniform costs: STATIC should finish in
        // ~total/P with tiny overhead.
        let topo = Topology::symmetric("t", 1, 10, 1.0, 1.0);
        let w = Workload::uniform("u", 10_000, 1e-6);
        let out = simulate(&topo, &cfg(Scheme::Static), &w, &costs());
        let ideal = w.total_cost() / 10.0;
        assert!(
            (out.makespan() - ideal) / ideal < 0.01,
            "makespan {} vs ideal {}",
            out.makespan(),
            ideal
        );
        assert!(out.report.cov() < 1e-6);
    }

    #[test]
    fn skewed_work_makes_static_imbalanced_and_gss_better() {
        // Heavy items all land in one STATIC block -> imbalance; GSS's
        // decreasing chunks smooth it out.
        // Light first half, heavy second half: STATIC parks whole heavy
        // blocks on half the workers; GSS reaches the heavy region with
        // small late chunks that spread across all workers. (Heavy-first
        // would instead land in GSS's big opening chunk — that case is
        // genuinely bad for GSS and not a scheduler defect.)
        let topo = Topology::symmetric("t", 1, 10, 1.0, 1.0);
        let items = 100_000;
        let per: Vec<f64> = (0..items)
            .map(|i| if i >= items / 2 { 90e-7 } else { 1e-7 })
            .collect();
        let w = Workload::from_costs("skew", &per);
        let stat = simulate(&topo, &cfg(Scheme::Static), &w, &costs());
        let gss = simulate(&topo, &cfg(Scheme::Gss), &w, &costs());
        assert!(
            gss.makespan() < stat.makespan() * 0.8,
            "gss {} vs static {}",
            gss.makespan(),
            stat.makespan()
        );
        assert!(stat.report.cov() > gss.report.cov());
    }

    #[test]
    fn ss_pays_heavy_contention() {
        // SS: one queue access per item, serialized -> makespan is at
        // least items * access_cost regardless of core count.
        let topo = Topology::broadwell20();
        let items = 50_000;
        let w = Workload::uniform("u", items, 1e-7);
        let out = simulate(&topo, &cfg(Scheme::Ss), &w, &costs());
        let floor = items as f64 * costs().queue_access;
        assert!(
            out.makespan() > floor,
            "SS makespan {} must exceed serialization floor {floor}",
            out.makespan()
        );
        // and must be far worse than MFSC on the same workload
        let mfsc = simulate(&topo, &cfg(Scheme::Mfsc), &w, &costs());
        assert!(out.makespan() > 3.0 * mfsc.makespan());
    }

    #[test]
    fn atomic_central_beats_locked_for_fine_chunks() {
        let topo = Topology::cascadelake56();
        let w = Workload::uniform("u", 200_000, 5e-8);
        let locked = simulate(&topo, &cfg(Scheme::Ss), &w, &costs());
        let atomic = simulate(
            &topo,
            &cfg(Scheme::Ss)
                .with_layout(QueueLayout::Centralized { atomic: true }),
            &w,
            &costs(),
        );
        assert!(
            atomic.makespan() < locked.makespan() / 2.0,
            "atomic {} vs locked {}",
            atomic.makespan(),
            locked.makespan()
        );
    }

    #[test]
    fn stealing_layouts_complete_and_steal_under_skew() {
        let topo = Topology::broadwell20();
        let items = 20_000;
        // all cost in the first block
        let per: Vec<f64> = (0..items)
            .map(|i| if i < 1000 { 1e-5 } else { 1e-8 })
            .collect();
        let w = Workload::from_costs("skew", &per);
        for victim in VictimStrategy::ALL {
            let config = cfg(Scheme::Fac2)
                .with_layout(QueueLayout::PerCore)
                .with_victim(victim);
            let out = simulate(&topo, &config, &w, &costs());
            assert_eq!(out.report.total_items(), items, "{victim:?}");
            assert!(out.report.total_steals() > 0, "{victim:?} never stole");
        }
    }

    #[test]
    fn remote_steals_cost_more_with_seqpri_less() {
        // SEQPRI keeps steals local first; with work only on socket 0,
        // socket-1 workers must go remote either way, but SEQPRI thieves
        // on socket 0 drain local victims first => fewer remote
        // executions than plain SEQ.
        let topo = Topology::broadwell20();
        let items = 40_000;
        let per: Vec<f64> = (0..items)
            .map(|i| if i < items / 2 { 2e-6 } else { 2e-8 })
            .collect();
        let w = Workload::from_costs("half", &per);
        let seq = simulate(
            &topo,
            &cfg(Scheme::Tss)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::Seq),
            &w,
            &costs(),
        );
        let seqpri = simulate(
            &topo,
            &cfg(Scheme::Tss)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::SeqPri),
            &w,
            &costs(),
        );
        // both complete; SEQPRI should not be slower by much (it can be
        // slightly slower in odd cases, so allow 10%)
        assert_eq!(seq.report.total_items(), items);
        assert!(seqpri.makespan() <= seq.makespan() * 1.1);
    }

    #[test]
    fn more_cores_shrink_makespan_for_balanced_work() {
        let w = Workload::uniform("u", 100_000, 1e-6);
        let m20 = simulate(
            &Topology::broadwell20(),
            &cfg(Scheme::Mfsc),
            &w,
            &costs(),
        );
        let m56 = simulate(
            &Topology::cascadelake56(),
            &cfg(Scheme::Mfsc),
            &w,
            &costs(),
        );
        assert!(
            m56.makespan() < m20.makespan() * 0.6,
            "56c {} vs 20c {}",
            m56.makespan(),
            m20.makespan()
        );
    }

    #[test]
    fn queue_busy_accounts_contention() {
        // single socket so every access costs exactly queue_access
        let topo = Topology::symmetric("t", 1, 20, 1.0, 1.0);
        let w = Workload::uniform("u", 10_000, 1e-7);
        let out = simulate(&topo, &cfg(Scheme::Ss), &w, &costs());
        // single central queue shared by 20 workers: busy time ~=
        // accesses * (queue_access * contenders), convoy-capped at 15
        let expect =
            out.acquisitions as f64 * costs().queue_access * 15.0;
        assert!((out.queue_busy[0] - expect).abs() / expect < 0.2);
    }

    #[test]
    fn flat_simulation_honours_per_place_speed_factors() {
        // A heterogeneous topology simulated directly (no pools): the
        // 2x-speed accelerator places must raise total throughput vs
        // the same worker count at uniform speed.
        use crate::topology::DeviceClass;
        let uniform = Topology::symmetric("u4", 1, 4, 1.0, 1.0);
        let hetero = Topology::heterogeneous(
            "h4",
            1,
            2,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        );
        let w = Workload::uniform("u", 40_000, 1e-6);
        let cfg = cfg(Scheme::Gss);
        let t_uniform = simulate(&uniform, &cfg, &w, &costs()).makespan();
        let t_hetero = simulate(&hetero, &cfg, &w, &costs()).makespan();
        // 4 cores at 1x vs 2 at 1x + 2 at 2x (= 6 core-equivalents)
        assert!(
            t_hetero < t_uniform * 0.85,
            "hetero {t_hetero} vs uniform {t_uniform}: per-place speed \
             factors must be modelled"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let topo = Topology::cascadelake56();
        let w = Workload::uniform("u", 30_000, 1e-7);
        let config = cfg(Scheme::Pss)
            .with_layout(QueueLayout::PerCore)
            .with_victim(VictimStrategy::RndPri)
            .with_seed(1234);
        let a = simulate(&topo, &config, &w, &costs());
        let b = simulate(&topo, &config, &w, &costs());
        assert_eq!(a.makespan(), b.makespan());
        assert_eq!(a.report.total_steals(), b.report.total_steals());
    }

    #[test]
    fn prop_sim_conserves_items_across_configs() {
        prop::check("sim executes every item once", 40, |rng| {
            let topo = if rng.below(2) == 0 {
                Topology::broadwell20()
            } else {
                Topology::cascadelake56()
            };
            let scheme = *rng.choose(&Scheme::ALL);
            let layout = *rng.choose(&[
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ]);
            let victim = *rng.choose(&VictimStrategy::ALL);
            let items = rng.range(1, 20_000) as usize;
            let per: Vec<f64> =
                (0..items).map(|_| rng.next_f64() * 1e-6).collect();
            let w = Workload::from_costs("rand", &per);
            let config = SchedConfig {
                scheme,
                layout,
                victim,
                seed: rng.next_u64(),
                stages: None,
                pls_swr: 0.5,
            };
            let out = simulate(&topo, &config, &w, &costs());
            prop::ensure(
                out.report.total_items() == items,
                format!(
                    "{scheme:?}/{layout:?}/{victim:?}: {} of {items}",
                    out.report.total_items()
                ),
            )?;
            prop::ensure(
                out.makespan() >= w.total_cost() / topo.n_cores() as f64 * 0.99
                    / topo.core_speed,
                "makespan below critical-path bound".to_string(),
            )
        });
    }
}
