//! Runtime values of the DaphneDSL subset, with the elementwise /
//! broadcast semantics the listings rely on.

use std::sync::Arc;

use crate::matrix::{CsrMatrix, DenseMatrix};

/// A DSL value.
#[derive(Debug, Clone)]
pub enum Value {
    Num(f64),
    Str(String),
    /// Dense matrix; `(n,1)` is a column vector, `(1,n)` a row vector.
    Mat(DenseMatrix),
    /// Sparse adjacency (from `readMatrix`).
    Sparse(Arc<CsrMatrix>),
    /// Lazy `G * t(c)`: the sparse pattern with stored entry `(r, j)`
    /// valued `scale[j]` — never materialised; consumed by `rowMaxs`.
    SparseColScaled(Arc<CsrMatrix>, Arc<Vec<f32>>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Mat(_) => "matrix",
            Value::Sparse(_) => "sparse-matrix",
            Value::SparseColScaled(..) => "sparse-product",
        }
    }

    pub fn as_num(&self) -> Result<f64, String> {
        match self {
            Value::Num(n) => Ok(*n),
            // 1x1 matrices coerce to scalars (DaphneDSL does the same)
            Value::Mat(m) if m.rows == 1 && m.cols == 1 => {
                Ok(m.data[0] as f64)
            }
            other => Err(format!("expected number, got {}", other.type_name())),
        }
    }

    pub fn as_mat(&self) -> Result<&DenseMatrix, String> {
        match self {
            Value::Mat(m) => Ok(m),
            other => Err(format!("expected matrix, got {}", other.type_name())),
        }
    }

    pub fn truthy(&self) -> Result<bool, String> {
        Ok(self.as_num()? != 0.0)
    }
}

/// How two dense shapes combine elementwise.
pub enum Broadcast {
    /// identical shapes
    Same,
    /// rhs is a `(1, d)` row vector broadcast down the rows
    Row,
    /// rhs is a `(n, 1)` column vector broadcast across the columns
    Col,
    /// rhs is a scalar-like `(1,1)`
    Scalar,
}

/// Determine the broadcast mode of `a (op) b`, if compatible.
pub fn broadcast_mode(
    a: &DenseMatrix,
    b: &DenseMatrix,
) -> Result<Broadcast, String> {
    if b.rows == 1 && b.cols == 1 {
        Ok(Broadcast::Scalar)
    } else if a.rows == b.rows && a.cols == b.cols {
        Ok(Broadcast::Same)
    } else if b.rows == 1 && b.cols == a.cols {
        Ok(Broadcast::Row)
    } else if b.cols == 1 && b.rows == a.rows {
        Ok(Broadcast::Col)
    } else {
        Err(format!(
            "incompatible shapes {}x{} vs {}x{}",
            a.rows, a.cols, b.rows, b.cols
        ))
    }
}

/// Apply `f` elementwise over a row range with broadcasting; writes into
/// `out[range]` (dense op kernel shared by the interpreter's scheduled
/// and sequential paths).
pub fn apply_rows(
    a: &DenseMatrix,
    b: &DenseMatrix,
    mode: &Broadcast,
    f: impl Fn(f32, f32) -> f32,
    out: &mut [f32],
    row_start: usize,
    row_end: usize,
) {
    let d = a.cols;
    for r in row_start..row_end {
        let arow = a.row(r);
        let orow = &mut out[(r - row_start) * d..(r - row_start + 1) * d];
        match mode {
            Broadcast::Same => {
                let brow = b.row(r);
                for c in 0..d {
                    orow[c] = f(arow[c], brow[c]);
                }
            }
            Broadcast::Row => {
                let brow = b.row(0);
                for c in 0..d {
                    orow[c] = f(arow[c], brow[c]);
                }
            }
            Broadcast::Col => {
                let bv = b[(r, 0)];
                for c in 0..d {
                    orow[c] = f(arow[c], bv);
                }
            }
            Broadcast::Scalar => {
                let bv = b.data[0];
                for c in 0..d {
                    orow[c] = f(arow[c], bv);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_coercion() {
        assert_eq!(Value::Num(2.5).as_num().unwrap(), 2.5);
        let m = DenseMatrix::from_vec(1, 1, vec![7.0]);
        assert_eq!(Value::Mat(m).as_num().unwrap(), 7.0);
        assert!(Value::Str("x".into()).as_num().is_err());
    }

    #[test]
    fn broadcast_modes() {
        let a = DenseMatrix::zeros(3, 4);
        assert!(matches!(
            broadcast_mode(&a, &DenseMatrix::zeros(3, 4)).unwrap(),
            Broadcast::Same
        ));
        assert!(matches!(
            broadcast_mode(&a, &DenseMatrix::zeros(1, 4)).unwrap(),
            Broadcast::Row
        ));
        assert!(matches!(
            broadcast_mode(&a, &DenseMatrix::zeros(3, 1)).unwrap(),
            Broadcast::Col
        ));
        assert!(matches!(
            broadcast_mode(&a, &DenseMatrix::zeros(1, 1)).unwrap(),
            Broadcast::Scalar
        ));
        assert!(broadcast_mode(&a, &DenseMatrix::zeros(2, 2)).is_err());
    }

    #[test]
    fn apply_rows_row_broadcast() {
        let a = DenseMatrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = DenseMatrix::from_vec(1, 2, vec![10., 20.]);
        let mut out = vec![0f32; 4];
        apply_rows(&a, &b, &Broadcast::Row, |x, y| x + y, &mut out, 0, 2);
        assert_eq!(out, vec![11., 22., 13., 24.]);
    }
}
