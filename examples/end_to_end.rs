//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real small workload and reports the paper's
//! headline metric.
//!
//! 1. **data substrate** — generate the synthetic Amazon co-purchase
//!    graph (the paper's dataset substitution) and report its shape;
//! 2. **DSL + VEE + scheduler** — run Listing 1 verbatim through the
//!    DaphneDSL interpreter under the default and best schedulers;
//! 3. **L1/L2/PJRT** — run the CC propagate and LinReg pipelines through
//!    the AOT Pallas artifacts and check numerics against native;
//! 4. **distributed (Fig. 5)** — coordinator + 3 workers on localhost;
//! 5. **headline reproduction** — Fig. 7a/7b on the modelled machines:
//!    MFSC vs the DAPHNE-default STATIC.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::collections::BTreeMap;
use std::net::TcpListener;

use daphne_sched::apps::{cc, linreg};
use daphne_sched::bench::{figures, FigureId, FigureParams};
use daphne_sched::config::SchedConfig;
use daphne_sched::coordinator::{worker, Leader};
use daphne_sched::dsl;
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::runtime::{DeviceService, Runtime};
use daphne_sched::sched::Scheme;
use daphne_sched::topology::Topology;
use daphne_sched::util::stats;
use daphne_sched::vee::Vee;

fn main() {
    println!("=== DaphneSched end-to-end validation ===\n");

    // ---------------------------------------------------------------
    // 1. data substrate
    // ---------------------------------------------------------------
    let nodes = 50_000;
    let g = amazon_like(&SnapGraph::small(nodes, 1)).symmetrize();
    let costs = g.row_costs();
    println!(
        "[1] graph: {} nodes, {} edges, density {:.5}%, row-nnz mean {:.1} \
         max {} (heavy-tailed, cov {:.2})",
        g.rows,
        g.nnz(),
        g.density() * 100.0,
        stats::mean(&costs),
        stats::max(&costs) as usize,
        stats::cov(&costs)
    );

    // ---------------------------------------------------------------
    // 2. DSL -> VEE -> scheduler, Listing 1 verbatim
    // ---------------------------------------------------------------
    let mut params = BTreeMap::new();
    params.insert(
        "f".to_string(),
        format!("synthetic:amazon?nodes={nodes}&seed=1"),
    );
    let host = Topology::host();
    for (label, scheme) in
        [("STATIC (DAPHNE default)", Scheme::Static), ("MFSC", Scheme::Mfsc)]
    {
        let vee = Vee::new(
            host.clone(),
            SchedConfig::default().with_scheme(scheme),
        );
        let out = dsl::run_script(dsl::LISTING_1_CC, &params, &vee).unwrap();
        println!(
            "[2] Listing 1 via DSL, {label:<24} diff={} iters={} \
             scheduled={:.4}s",
            out.num("diff").unwrap(),
            out.num("iter").unwrap(),
            out.scheduled_time()
        );
    }

    // ---------------------------------------------------------------
    // 3. PJRT artifacts (L1 Pallas -> L2 JAX -> HLO -> rust)
    // ---------------------------------------------------------------
    if Runtime::default_dir().join("manifest.json").exists() {
        let (service, client) = DeviceService::start_default().unwrap();
        println!(
            "[3] pjrt: platform {}, {} stages loaded",
            service.platform,
            service.manifest.stages.len()
        );
        // CC through the Pallas artifact on a small graph
        let gs = amazon_like(&SnapGraph::small(600, 3)).symmetrize();
        let sched = SchedConfig::default().with_scheme(Scheme::Gss);
        let native = cc::run_native(&gs, &host, &sched, 100);
        let pjrt = cc::run_pjrt(&gs, &client, &service.manifest, &host, &sched, 100)
            .unwrap();
        assert_eq!(native.labels, pjrt.labels);
        println!(
            "    cc_propagate artifact == native kernel on {} labels \
             ({} iterations)",
            pjrt.labels.len(),
            pjrt.iterations
        );
        // LinReg through the fused artifact
        let (_, d) = service.manifest.lr_block;
        let spec = linreg::LinregSpec {
            rows: 2048,
            cols: d + 1,
            lambda: 1e-3,
            seed: 3,
        };
        let (x, y) = linreg::generate(&spec);
        let nat = linreg::run_native(&x, &y, 1e-3, &host, &sched).unwrap();
        let pj = linreg::run_pjrt(
            &x,
            &y,
            1e-3,
            &client,
            &service.manifest,
            &host,
            &sched,
        )
        .unwrap();
        let max_diff = nat
            .beta
            .iter()
            .zip(&pj.beta)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "    lr_fused artifact beta max |diff| vs native = {max_diff:.2e}"
        );
    } else {
        println!("[3] SKIPPED: run `make artifacts` first");
    }

    // ---------------------------------------------------------------
    // 4. distributed coordinator (Fig. 5)
    // ---------------------------------------------------------------
    let mut addrs = Vec::new();
    for i in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap());
        let vee = Vee::new(
            Topology::host(),
            SchedConfig::default().with_scheme(Scheme::Gss).with_seed(i),
        );
        std::thread::spawn(move || worker::serve(listener, vee, Some(1)));
    }
    let mut leader = Leader::connect(&addrs).unwrap();
    let dist = leader.cc_distributed(&g, 100).unwrap();
    leader.shutdown().unwrap();
    let local = cc::run_native(&g, &host, &SchedConfig::default(), 100);
    assert_eq!(dist.labels, local.labels);
    println!(
        "[4] distributed cc over 3 workers: {} iterations, labels match local"
        , dist.iterations
    );

    // ---------------------------------------------------------------
    // 5. headline: Fig 7a / 7b MFSC vs STATIC on the modelled machines
    // ---------------------------------------------------------------
    println!("[5] headline reproduction (modelled machines, 3 repetitions):");
    let params = FigureParams { iterations: Some(10), ..Default::default() };
    for (id, paper_gain) in [(FigureId::Fig7a, 13.2), (FigureId::Fig7b, 8.3)] {
        let rows = figures::run_figure(id, &params);
        let mfsc = rows.iter().find(|r| r.scheme == "MFSC").unwrap();
        let gain = (1.0 - mfsc.vs_static) * 100.0;
        println!(
            "    {}: MFSC vs STATIC: measured {gain:+.1}% (paper {paper_gain:+.1}%)",
            id.name()
        );
        assert!(
            mfsc.vs_static < 1.0,
            "MFSC must beat STATIC on the sparse workload"
        );
    }
    println!("\nall layers compose: OK");
}
