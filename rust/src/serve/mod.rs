//! Open-loop request serving on the real executor.
//!
//! A long-running service in front of [`crate::sched::Executor`]: an
//! open-loop generator emits a continuous stream of small pipeline
//! instances — linreg-inference prefixes or cc queries — at a target
//! QPS under the [`SERVE_TAG`] tenant tag while batch tenants run
//! underneath, and every arrival passes through the same
//! [`AdmissionPolicy`] check the DES mirror
//! ([`crate::sim::serve::replay_open_loop`]) applies in virtual time.
//! The generator does not wait for responses (that is what "open loop"
//! means): under overload the backlog grows, and the admission policy —
//! not an unbounded queue — decides what happens next:
//!
//! - [`AdmissionPolicy::Open`] admits everything; queueing delay (and
//!   with it the p99/p999 tail) diverges once offered load passes
//!   capacity.
//! - [`AdmissionPolicy::Bounded`] caps the live-job backlog per tag, so
//!   the served tail stays bounded and the excess is counted as shed.
//! - [`AdmissionPolicy::Shed`] rejects when `backlog × est_cost`
//!   exceeds a deadline — a latency-denominated bound.
//!
//! Per-request latency lands in a bounded, seeded
//! [`LatencyReservoir`]; [`ServeReport`] carries sustained throughput,
//! p50/p99/p999, SLO attainment over served requests, shed counts, and
//! the accept/reject decision sequence (what the DES-agreement
//! integration test compares). Drive it from the CLI:
//!
//! ```text
//! daphne-sched serve qps=400 duration=2 slo_ms=10 admission=bounded \
//!     max_backlog=4 policy=fair requests=linreg
//! ```
//!
//! The arrival trace is [`crate::sim::serve::arrival_times`] — the
//! exact offsets the DES replays — so a `figure serve` prediction and a
//! real soak see the same offered load, seed for seed.

use std::hint::black_box;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::ArrivalPattern;
use crate::obs::MetricsSnapshot;
use crate::sched::{
    Admitted, AdmissionPolicy, ControllerCfg, Executor, GraphError,
    GraphHandle, GraphSpec, NodeSpec, ScaleDecision, ScalingController,
    Signals, SubmitOpts, TenancyPolicy,
};
use crate::sim::serve::{arrival_times, RESERVOIR_CAPACITY, SERVE_TAG};
use crate::util::json::Json;
use crate::util::stats::{self, LatencyReservoir};

/// Tag of the batch tenants running underneath the request stream.
pub const BATCH_TAG: &str = "batch";

/// Which request pipeline the generator emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    /// The linreg-inference prefix: colstats → stats → standardize
    /// (the first three nodes of the training pipeline — what scoring
    /// a batch of rows against a fitted model exercises).
    Linreg,
    /// A cc query: propagate → diff (one label-propagation round).
    Cc,
}

impl RequestKind {
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::Linreg => "linreg",
            RequestKind::Cc => "cc",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linreg" | "lr" => Some(RequestKind::Linreg),
            "cc" => Some(RequestKind::Cc),
            _ => None,
        }
    }
}

/// Burn roughly `iters` ALU iterations — the per-item request body.
/// Real work (not a sleep), so requests contend for cores with the
/// batch tenants exactly as pipeline operators would.
fn spin(iters: u64) {
    let mut acc = 0u64;
    for i in 0..iters {
        acc = acc.wrapping_add(black_box(i ^ 0x9E37_79B9_7F4A_7C15));
    }
    black_box(acc);
}

/// One linreg-inference request: the training pipeline's standardize
/// prefix as an owned-body graph (`work` spin iterations per item).
pub fn linreg_request(rows: usize, work: u64) -> GraphSpec<'static> {
    let body = move |_w: usize, r: crate::sched::TaskRange| {
        for _ in r.start..r.end {
            spin(work);
        }
    };
    GraphSpec::new("linreg-infer")
        .node(NodeSpec::new("colstats", rows), body)
        .node(NodeSpec::new("stats", 1).after("colstats"), body)
        .node(NodeSpec::new("standardize", rows).after("stats"), body)
}

/// One cc query: a single propagate round plus its convergence check.
pub fn cc_request(rows: usize, work: u64) -> GraphSpec<'static> {
    let body = move |_w: usize, r: crate::sched::TaskRange| {
        for _ in r.start..r.end {
            spin(work);
        }
    };
    GraphSpec::new("cc-query")
        .node(NodeSpec::new("propagate", rows), body)
        .node(NodeSpec::new("diff", rows).after("propagate"), body)
}

fn build_request(kind: RequestKind, rows: usize, work: u64) -> GraphSpec<'static> {
    match kind {
        RequestKind::Linreg => linreg_request(rows, work),
        RequestKind::Cc => cc_request(rows, work),
    }
}

/// One wide batch graph (a long single-node sweep under [`BATCH_TAG`]).
fn batch_graph(idx: usize, items: usize, work: u64) -> GraphSpec<'static> {
    let body = move |_w: usize, r: crate::sched::TaskRange| {
        for _ in r.start..r.end {
            spin(work);
        }
    };
    GraphSpec::new(&format!("batch{idx}"))
        .node(NodeSpec::new("sweep", items), body)
}

/// One open-loop soak: the request stream, its rate and SLO, the
/// admission setting, and the batch load underneath.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub requests: RequestKind,
    /// Items per parallel request node (request width).
    pub rows: usize,
    /// Spin iterations per item (request weight).
    pub work: u64,
    /// Offered load, requests per second.
    pub qps: f64,
    /// Arrival-window length in seconds.
    pub duration: f64,
    /// Arrivals before this offset are served but not measured.
    pub warmup: f64,
    /// Latency SLO in seconds.
    pub slo: f64,
    /// Admission applied to every request arrival.
    pub admission: AdmissionPolicy,
    /// Estimated service seconds per backlog entry (the `Shed` input;
    /// also what `figure serve` uses in the DES).
    pub est_cost: f64,
    /// Arrival pattern of the generator.
    pub arrival: ArrivalPattern,
    /// Seed for the arrival trace and the latency reservoir.
    pub seed: u64,
    /// Priority of every request (for `policy=priority`).
    pub priority: i64,
    /// Fair-share weight of the [`SERVE_TAG`] tag (for `policy=fair`).
    pub weight: u64,
    /// Number of batch graphs running underneath (0 = requests only).
    pub batch_tenants: usize,
    /// Items per batch graph — size these past the soak so batch
    /// pressure lasts the whole window (leftovers are cancelled).
    pub batch_items: usize,
    /// Seconds between [`MetricsSnapshot`]s of the live
    /// [`crate::obs::MetricsRegistry`] during the soak (0 = none).
    pub metrics_interval: f64,
    /// Run the SLO-driven [`ScalingController`] during the soak: the
    /// serving pool (pool 0) borrows workers from the accelerator pool
    /// (pool 1) on sustained SLO breach and gives them back when the
    /// donor gets busy or steals keep failing. No-op on single-pool
    /// topologies.
    pub elastic: bool,
    /// Controller width floor for the serving pool (0 = its base
    /// width — never reclaim below the resident workers).
    pub min_workers: usize,
    /// Controller width ceiling for the serving pool (0 = base width
    /// plus every donor worker).
    pub max_workers: usize,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            requests: RequestKind::Linreg,
            rows: 32,
            work: 2_000,
            qps: 200.0,
            duration: 1.0,
            warmup: 0.2,
            slo: 0.010,
            admission: AdmissionPolicy::Open,
            est_cost: 0.0,
            arrival: ArrivalPattern::Uniform,
            seed: 42,
            priority: 2,
            weight: 4,
            batch_tenants: 1,
            batch_items: 1 << 20,
            metrics_interval: 0.0,
            elastic: false,
            min_workers: 0,
            max_workers: 0,
        }
    }
}

/// Serving metrics of one [`run_serve`] soak — the real-executor
/// counterpart of [`crate::sim::serve::ServeSimOutcome`], field for
/// field.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: TenancyPolicy,
    pub admission: AdmissionPolicy,
    /// Requests generated over the whole window.
    pub offered: usize,
    /// Requests arriving inside the measurement window (≥ warmup).
    pub measured: usize,
    /// Measured requests admitted and completed successfully.
    pub served: usize,
    /// Measured requests rejected at admission.
    pub shed: usize,
    /// Measured requests admitted but not completed (node failure).
    pub failed: usize,
    /// Served requests per second over the measurement window.
    pub attained_qps: f64,
    /// Latency percentiles over served measured requests (seconds).
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    /// Fraction of served measured requests within the SLO.
    pub slo_attainment: f64,
    /// Mean admission → first-dispatch delay of served measured
    /// requests (from the root node's `SchedReport::queue_delay`).
    pub mean_queue_delay: f64,
    /// Wall-clock seconds of the whole soak (drain included).
    pub wall: f64,
    /// Accept/reject per request in arrival order (warmup included).
    pub decisions: Vec<bool>,
    /// Interval snapshots of the live metrics registry (empty when
    /// `metrics_interval` is 0); cumulative counters, see
    /// [`MetricsSnapshot`]. The final entry is taken after the drain.
    pub metrics: Vec<MetricsSnapshot>,
    /// Non-`Hold` controller decisions in issue order (empty unless
    /// `elastic` was on and the controller acted).
    pub scale_decisions: Vec<ScaleDecision>,
}

impl ServeReport {
    /// Fraction of measured requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.measured == 0 {
            0.0
        } else {
            self.shed as f64 / self.measured as f64
        }
    }

    /// One aligned table row: admission, attained/offered, tail, SLO.
    pub fn row(&self) -> String {
        format!(
            "{:<8} {:>8.1} {:>7} {:>6} {:>6} {:>9.2} {:>9.2} {:>9.2} {:>6.1}%",
            self.admission.name(),
            self.attained_qps,
            self.served,
            self.shed,
            self.failed,
            self.p50 * 1e3,
            self.p99 * 1e3,
            self.p999 * 1e3,
            self.slo_attainment * 100.0,
        )
    }

    /// Header matching [`ServeReport::row`].
    pub fn header() -> String {
        format!(
            "{:<8} {:>8} {:>7} {:>6} {:>6} {:>9} {:>9} {:>9} {:>6}",
            "admit", "qps", "served", "shed", "failed", "p50ms", "p99ms",
            "p999ms", "slo"
        )
    }

    /// Stable JSON form for `report=json` bench reports
    /// ([`crate::obs::BenchReport`]). Decisions are collapsed to a
    /// count (the full accept/reject sequence is an in-process
    /// comparison artifact, not a report metric).
    pub fn to_json(&self) -> Json {
        let snap = |m: &MetricsSnapshot| {
            Json::Obj(
                [
                    ("t".to_string(), Json::Num(m.t)),
                    ("admitted".to_string(), Json::Num(m.admitted as f64)),
                    ("shed".to_string(), Json::Num(m.shed as f64)),
                    (
                        "backlog_high_water".to_string(),
                        Json::Num(m.backlog_high_water as f64),
                    ),
                    ("enqueued".to_string(), Json::Num(m.enqueued as f64)),
                    ("completed".to_string(), Json::Num(m.completed as f64)),
                    ("cancelled".to_string(), Json::Num(m.cancelled as f64)),
                    ("steals".to_string(), Json::Num(m.steals as f64)),
                    (
                        "failed_steals".to_string(),
                        Json::Num(m.failed_steals as f64),
                    ),
                    ("parks".to_string(), Json::Num(m.parks as f64)),
                    ("unparks".to_string(), Json::Num(m.unparks as f64)),
                    ("repicks".to_string(), Json::Num(m.repicks as f64)),
                    ("resizes".to_string(), Json::Num(m.resizes as f64)),
                    ("pool_width".to_string(), {
                        let n = m
                            .pool_width
                            .iter()
                            .rposition(|&w| w > 0)
                            .map_or(0, |i| i + 1);
                        Json::Arr(
                            m.pool_width[..n]
                                .iter()
                                .map(|&w| Json::Num(w as f64))
                                .collect(),
                        )
                    }),
                ]
                .into_iter()
                .collect(),
            )
        };
        Json::Obj(
            [
                (
                    "policy".to_string(),
                    Json::Str(self.policy.name().to_string()),
                ),
                (
                    "admission".to_string(),
                    Json::Str(self.admission.name().to_string()),
                ),
                ("offered".to_string(), Json::Num(self.offered as f64)),
                ("measured".to_string(), Json::Num(self.measured as f64)),
                ("served".to_string(), Json::Num(self.served as f64)),
                ("shed".to_string(), Json::Num(self.shed as f64)),
                ("failed".to_string(), Json::Num(self.failed as f64)),
                ("attained_qps".to_string(), Json::Num(self.attained_qps)),
                ("p50".to_string(), Json::Num(self.p50)),
                ("p99".to_string(), Json::Num(self.p99)),
                ("p999".to_string(), Json::Num(self.p999)),
                (
                    "slo_attainment".to_string(),
                    Json::Num(self.slo_attainment),
                ),
                (
                    "mean_queue_delay".to_string(),
                    Json::Num(self.mean_queue_delay),
                ),
                ("wall".to_string(), Json::Num(self.wall)),
                (
                    "decisions".to_string(),
                    Json::Num(self.decisions.len() as f64),
                ),
                (
                    "metrics".to_string(),
                    Json::Arr(self.metrics.iter().map(snap).collect()),
                ),
                (
                    "scale_decisions".to_string(),
                    Json::Arr(
                        self.scale_decisions
                            .iter()
                            .map(|d| Json::Str(d.describe()))
                            .collect(),
                    ),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

struct InFlight {
    handle: GraphHandle<'static>,
    /// Wall offset (seconds from soak start) of the actual submission.
    submitted: f64,
    /// Arrived inside the measurement window.
    measured: bool,
}

struct Tally {
    reservoir: LatencyReservoir,
    queue_delays: Vec<f64>,
    served: usize,
    failed: usize,
    within_slo: usize,
    last_finish: f64,
}

impl Tally {
    fn settle(&mut self, f: InFlight, slo: f64) {
        let report = f.handle.join();
        if !f.measured {
            return;
        }
        if !report.all_completed() {
            self.failed += 1;
            return;
        }
        let latency = report.makespan;
        let qd = report
            .nodes
            .first()
            .and_then(|n| n.report.as_ref())
            .map(|r| r.queue_delay)
            .unwrap_or(0.0);
        self.served += 1;
        self.reservoir.record(latency);
        self.queue_delays.push(qd);
        if latency <= slo {
            self.within_slo += 1;
        }
        self.last_finish = self.last_finish.max(f.submitted + latency);
    }
}

/// Drain every finished in-flight request without blocking.
fn drain_finished(inflight: &mut Vec<InFlight>, tally: &mut Tally, slo: f64) {
    let mut i = 0;
    while i < inflight.len() {
        if inflight[i].handle.is_finished() {
            let f = inflight.swap_remove(i);
            tally.settle(f, slo);
        } else {
            i += 1;
        }
    }
}

/// Run one open-loop soak on `exec`: batch tenants submitted up front
/// under [`BATCH_TAG`], then the request stream paced on the wall clock
/// along the seeded arrival trace, each arrival admission-checked via
/// [`crate::sched::Session::try_submit_graph`]. Blocks until every
/// admitted request drains (batch leftovers are cancelled), so the
/// report is complete.
pub fn run_serve(exec: &Executor, spec: &ServeSpec) -> Result<ServeReport, GraphError> {
    let arrivals =
        arrival_times(spec.arrival, spec.qps, spec.duration, spec.seed);
    let session = exec.session();

    let mut batch_handles = Vec::with_capacity(spec.batch_tenants);
    for b in 0..spec.batch_tenants {
        batch_handles.push(session.submit_graph(
            batch_graph(b, spec.batch_items, spec.work),
            SubmitOpts::new().tag(BATCH_TAG),
        )?);
    }

    let mut tally = Tally {
        reservoir: LatencyReservoir::new(
            RESERVOIR_CAPACITY,
            spec.seed ^ 0x7E5E,
        ),
        queue_delays: Vec::new(),
        served: 0,
        failed: 0,
        within_slo: 0,
        last_finish: 0.0,
    };
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut decisions = Vec::with_capacity(arrivals.len());
    let (mut measured, mut shed) = (0usize, 0usize);
    let mut metrics_log: Vec<MetricsSnapshot> = Vec::new();
    let mut next_snap = spec.metrics_interval;
    if spec.metrics_interval > 0.0 || spec.elastic {
        // the registry is process-cumulative; zero it so snapshots (and
        // the controller's high-water / steal-ratio signals) read as
        // this soak's counters
        crate::obs::metrics().reset();
    }

    // Elastic scaling: the serving pool (0) borrows from the
    // accelerator pool (1) under controller decisions. Signals come
    // from the same surfaces the report quotes — the latency reservoir,
    // the live counters (the steal-ratio reclaim path needs `trace=on`;
    // with tracing off the ratio reads 0 and that path stays inert),
    // and the donor's non-moldable queue backlog.
    let mut controller = if spec.elastic && exec.elastic().n_pools() >= 2 {
        let base = exec.elastic().width(0);
        let donor_cap = exec.elastic().width(1);
        let cfg = ControllerCfg {
            slo: spec.slo,
            min_workers: if spec.min_workers > 0 { spec.min_workers } else { base },
            max_workers: if spec.max_workers > 0 {
                spec.max_workers
            } else {
                base + donor_cap
            },
            ..ControllerCfg::default()
        };
        crate::obs::metrics().set_pool_widths(&exec.elastic().widths());
        Some(ScalingController::new(cfg))
    } else {
        None
    };
    let ctl_interval = if spec.metrics_interval > 0.0 {
        spec.metrics_interval
    } else {
        0.05
    };
    let mut next_ctl = ctl_interval;
    let mut scale_decisions: Vec<ScaleDecision> = Vec::new();
    let (mut prev_steals, mut prev_failed) = (0u64, 0u64);

    let start = Instant::now();
    for &t in &arrivals {
        // pace the generator, reaping completions while idle
        loop {
            let now = start.elapsed().as_secs_f64();
            if now >= t {
                break;
            }
            drain_finished(&mut inflight, &mut tally, spec.slo);
            if spec.metrics_interval > 0.0 && now >= next_snap {
                metrics_log.push(crate::obs::metrics().snapshot(now));
                next_snap += spec.metrics_interval;
            }
            if controller.is_some() && now >= next_ctl {
                let ctl = controller.as_mut().unwrap();
                let m = crate::obs::metrics().snapshot(now);
                let attempts = (m.steals + m.failed_steals)
                    .saturating_sub(prev_steals + prev_failed);
                let fails = m.failed_steals.saturating_sub(prev_failed);
                prev_steals = m.steals;
                prev_failed = m.failed_steals;
                let sig = Signals {
                    p99: tally.reservoir.p99(),
                    backlog: m.backlog_high_water,
                    failed_steal_ratio: if attempts > 0 {
                        fails as f64 / attempts as f64
                    } else {
                        0.0
                    },
                    donor_busy: exec.pool_backlog(1) > 0,
                    width: exec.elastic().width(0),
                };
                match ctl.decide(&sig) {
                    ScaleDecision::Hold => {}
                    d @ ScaleDecision::Lend(n) => {
                        if session.lend(1, 0, n) > 0 {
                            scale_decisions.push(d);
                        }
                    }
                    ScaleDecision::Reclaim => {
                        if session.reclaim(1) > 0 {
                            scale_decisions.push(ScaleDecision::Reclaim);
                        }
                    }
                }
                next_ctl += ctl_interval;
            }
            let wait = (t - start.elapsed().as_secs_f64()).max(0.0);
            thread::sleep(Duration::from_secs_f64(wait.min(2e-4)));
        }
        let in_window = t >= spec.warmup;
        if in_window {
            measured += 1;
        }
        let opts = SubmitOpts::new()
            .tag(SERVE_TAG)
            .priority(spec.priority)
            .weight(spec.weight)
            .admission(spec.admission)
            .est_cost(spec.est_cost);
        let req = build_request(spec.requests, spec.rows, spec.work);
        match session.try_submit_graph(req, opts)? {
            Admitted::Accepted(handle) => {
                decisions.push(true);
                inflight.push(InFlight {
                    handle,
                    submitted: start.elapsed().as_secs_f64(),
                    measured: in_window,
                });
            }
            Admitted::Rejected { .. } => {
                decisions.push(false);
                if in_window {
                    shed += 1;
                }
            }
        }
    }

    // drain: every admitted request runs to terminal
    for f in inflight.drain(..) {
        tally.settle(f, spec.slo);
    }
    // release the pool: batch leftovers are cancelled, not awaited
    for h in batch_handles {
        h.cancel();
        h.join();
    }
    // restore the base pool assignment before the executor outlives
    // this soak
    if controller.is_some() {
        session.reclaim(1);
    }
    if spec.metrics_interval > 0.0 {
        metrics_log
            .push(crate::obs::metrics().snapshot(start.elapsed().as_secs_f64()));
    }

    let span = (tally.last_finish - spec.warmup)
        .max(spec.duration - spec.warmup)
        .max(f64::MIN_POSITIVE);
    Ok(ServeReport {
        policy: exec.policy(),
        admission: spec.admission,
        offered: arrivals.len(),
        measured,
        served: tally.served,
        shed,
        failed: tally.failed,
        attained_qps: tally.served as f64 / span,
        p50: tally.reservoir.p50(),
        p99: tally.reservoir.p99(),
        p999: tally.reservoir.p999(),
        slo_attainment: if tally.served == 0 {
            0.0
        } else {
            tally.within_slo as f64 / tally.served as f64
        },
        mean_queue_delay: stats::mean(&tally.queue_delays),
        wall: start.elapsed().as_secs_f64(),
        decisions,
        metrics: metrics_log,
        scale_decisions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedConfig;
    use crate::topology::Topology;
    use std::sync::Arc;

    fn exec(policy: TenancyPolicy) -> Executor {
        Executor::new_with_policy(
            Arc::new(Topology::symmetric("t4", 1, 4, 1.5, 1.0)),
            Arc::new(SchedConfig::fine_grained()),
            policy,
        )
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock soak on real threads")]
    fn open_soak_serves_everything_offered() {
        let exec = exec(TenancyPolicy::Fifo);
        let spec = ServeSpec {
            qps: 100.0,
            duration: 0.2,
            warmup: 0.0,
            work: 200,
            rows: 8,
            batch_tenants: 0,
            slo: 5.0, // generous: correctness, not performance
            ..ServeSpec::default()
        };
        let report = run_serve(&exec, &spec).unwrap();
        assert_eq!(report.offered, 20);
        assert_eq!(report.decisions.len(), 20);
        assert!(report.decisions.iter().all(|&d| d), "open admits all");
        assert_eq!(report.measured, 20);
        assert_eq!(report.served, 20);
        assert_eq!(report.shed, 0);
        assert_eq!(report.failed, 0);
        assert_eq!(report.slo_attainment, 1.0);
        assert!(report.attained_qps > 0.0);
        assert!(report.p50 > 0.0 && report.p999 >= report.p50);
        // JSON form round-trips through the report serializer
        let j = crate::util::json::parse(&crate::util::json::to_string(
            &report.to_json(),
        ))
        .unwrap();
        assert_eq!(j.get("policy").and_then(Json::as_str), Some("fifo"));
        assert_eq!(j.get("admission").and_then(Json::as_str), Some("open"));
        assert_eq!(j.get("served").and_then(Json::as_f64), Some(20.0));
        assert_eq!(j.get("decisions").and_then(Json::as_f64), Some(20.0));
        assert!(j.get("metrics").and_then(Json::as_arr).is_some());
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock soak on real threads")]
    fn burst_bounded_admits_exactly_the_first_k() {
        // all arrivals at t=0 with requests heavy enough that none can
        // finish inside the sub-millisecond submission sweep: the
        // accept/reject sequence is first-k deterministic, matching the
        // DES (sim::serve burst test / the integration test)
        let exec = exec(TenancyPolicy::Fifo);
        let spec = ServeSpec {
            arrival: ArrivalPattern::Burst,
            qps: 60.0,
            duration: 0.1, // 6 requests, all at t=0
            warmup: 0.0,
            rows: 16,
            work: 3_000_000,
            batch_tenants: 0,
            admission: AdmissionPolicy::Bounded { max_backlog: 2 },
            slo: 30.0,
            ..ServeSpec::default()
        };
        let report = run_serve(&exec, &spec).unwrap();
        let expected: Vec<bool> = (0..6).map(|i| i < 2).collect();
        assert_eq!(report.decisions, expected);
        assert_eq!(report.served, 2);
        assert_eq!(report.shed, 4);
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock soak on real threads")]
    fn elastic_soak_restores_pools_and_reports_decisions() {
        use crate::topology::DeviceClass;
        let exec = Executor::new_with_policy(
            Arc::new(Topology::heterogeneous(
                "h",
                1,
                2,
                1.0,
                1.0,
                &[(DeviceClass::Gpu, 2, 2.0)],
            )),
            Arc::new(SchedConfig::fine_grained()),
            TenancyPolicy::Fifo,
        );
        let spec = ServeSpec {
            qps: 200.0,
            duration: 0.3,
            warmup: 0.0,
            work: 2_000,
            rows: 16,
            batch_tenants: 0,
            slo: 0.0005, // tight on purpose: give the controller breaches
            elastic: true,
            metrics_interval: 0.02,
            ..ServeSpec::default()
        };
        let report = run_serve(&exec, &spec).unwrap();
        // whatever the controller did mid-soak, the base assignment is
        // restored before the executor outlives the soak
        assert_eq!(exec.elastic().lent_out(1), 0);
        assert_eq!(exec.elastic().width(0), 2);
        assert_eq!(exec.elastic().width(1), 2);
        assert_eq!(report.failed, 0);
        let j = crate::util::json::parse(&crate::util::json::to_string(
            &report.to_json(),
        ))
        .unwrap();
        let dec = j
            .get("scale_decisions")
            .and_then(Json::as_arr)
            .expect("scale_decisions array");
        assert_eq!(dec.len(), report.scale_decisions.len());
        // interval snapshots carry the width gauges
        let metrics = j.get("metrics").and_then(Json::as_arr).unwrap();
        assert!(metrics
            .iter()
            .all(|m| m.get("pool_width").and_then(Json::as_arr).is_some()));
    }

    #[test]
    fn request_graphs_are_valid_and_named_like_the_pipelines() {
        let lr = linreg_request(8, 1);
        assert_eq!(
            lr.node_names().collect::<Vec<_>>(),
            ["colstats", "stats", "standardize"]
        );
        let cc = cc_request(8, 1);
        assert_eq!(
            cc.node_names().collect::<Vec<_>>(),
            ["propagate", "diff"]
        );
        assert_eq!(RequestKind::parse("LinReg"), Some(RequestKind::Linreg));
        assert_eq!(RequestKind::parse("cc"), Some(RequestKind::Cc));
        assert_eq!(RequestKind::parse("nope"), None);
    }
}
